//! Hazard-derived dependence graph + the list scheduler.
//!
//! There is exactly one hazard model in the repo — `sim::hazard`'s windows
//! plus the issue charges `Machine::step_plan` applies — and this module
//! consumes it instead of mirroring it: [`CostModel`] computes the same
//! per-instruction charge the machine will, and the same writer-visibility
//! windows (`REG_WINDOW`, `DOT_WINDOW`, `MEM_WINDOW`, the LOD streaming
//! extension `REG_WINDOW + charge - waves`, the DOT/SUM writeback
//! `waves + DOT_WINDOW`).
//!
//! Scheduling is chain-structured. A *chain* is a run of instructions
//! between control boundaries (labels, JMP/JSR/RTS/LOOP/STOP); within a
//! chain, predicate ops (IF/ELSE/ENDIF) split *segments* that may not
//! exchange instructions (the write-enable gate differs across them) but
//! share hazard timing. Every chain begins with a clean pipeline — the
//! scheduler settles (pads) before every control transfer and before
//! fall-through into a label, which is what makes the per-chain analysis
//! globally sound: every dynamic path into a chain has all windows
//! expired. This is the structural form of the `Sched::fence` discipline
//! (and of the control-flow auto-fence fix in `kernels::sched`).
//!
//! Three strategies produce a [`Layout`] from the same IR:
//!
//! - **Fenced** — original order, full pipeline settle before every
//!   instruction. The schedule-disabled oracle: trivially hazard-free and
//!   the slowest correct program.
//! - **Linear** — original order, minimal RAW/memory padding. Exactly what
//!   the legacy `kernels::Sched` emitter produced: "padding the delay
//!   slots".
//! - **List** — per segment, a priority list schedule that moves
//!   independent instructions *into* the delay slots and pads only the
//!   residual slack. Per chain the result is compared against Linear and
//!   the better one kept, so List ≤ Linear ≤ Fenced in cycles by
//!   construction.

use crate::isa::{Opcode, ThreadCtrl, WAVEFRONT_WIDTH};
use crate::sim::config::MemoryMode;
use crate::sim::hazard::{DOT_WINDOW, MEM_WINDOW, REG_WINDOW};

use super::ir::{Item, KernelBuilder, Node};
use super::SchedMode;

/// Flattened builder output: nodes and labels with a stable order.
pub(crate) struct Flat {
    pub nodes: Vec<Node>,
    pub labels: Vec<String>,
    pub order: Vec<Slot>,
    pub nvals: u32,
}

/// One emitted position: a real instruction, an inserted NOP, or a label
/// (labels occupy no instruction address but do occupy a slot).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Slot {
    Node(usize),
    Pad,
    Label(usize),
}

/// A fully scheduled instruction stream with its cycle timeline.
pub(crate) struct Layout {
    pub slots: Vec<Slot>,
    /// Issue-start cycle of each slot (straight-line model; labels carry
    /// the cycle at which they are reached).
    pub starts: Vec<u64>,
    /// Straight-line cycle estimate (loop bodies counted once).
    pub end_cycle: u64,
    pub nops: usize,
    /// Slot position of each label (for back-edge classification).
    pub label_pos: Vec<usize>,
}

pub(crate) fn flatten(b: &KernelBuilder) -> Flat {
    let mut nodes = Vec::new();
    let mut labels = Vec::new();
    let mut order = Vec::new();
    for item in &b.items {
        match item {
            Item::Label(name) => {
                order.push(Slot::Label(labels.len()));
                labels.push(name.clone());
            }
            Item::Node(n) => {
                order.push(Slot::Node(nodes.len()));
                nodes.push(n.clone());
            }
        }
    }
    Flat {
        nodes,
        labels,
        order,
        nvals: b.nvals,
    }
}

/// The machine's issue-cost and hazard-window model, parameterized the way
/// a `Machine` instance is (runtime thread count, memory organization).
/// The port-charge formulas are *shared* with the machine
/// ([`MemoryMode::load_cycles`]/[`MemoryMode::store_cycles`], which
/// `SharedMem` delegates to), not copied.
#[derive(Debug, Clone, Copy)]
pub(crate) struct CostModel {
    total_waves: usize,
    memory: MemoryMode,
}

impl CostModel {
    pub fn new(threads: usize, memory: MemoryMode) -> CostModel {
        CostModel {
            total_waves: threads / WAVEFRONT_WIDTH,
            memory,
        }
    }

    fn geometry(&self, tc: ThreadCtrl) -> (u64, u64) {
        let waves = tc.depth.waves(self.total_waves) as u64;
        let sel = waves * tc.width.lanes() as u64;
        (waves, sel)
    }

    /// Cycles `Machine::step_plan` charges for this instruction.
    pub fn cost(&self, n: &Node) -> u64 {
        use crate::isa::Group;
        let (waves, sel) = self.geometry(n.tc);
        match n.op.group() {
            Group::Nop | Group::Control => 1,
            Group::Memory => {
                if n.op == Opcode::Lod {
                    self.memory.load_cycles(sel as usize)
                } else {
                    self.memory.store_cycles(sel as usize)
                }
            }
            _ => waves,
        }
    }

    /// Writer-visibility window for this instruction's register result
    /// (cycles from issue start until a reader may start).
    pub fn def_window(&self, n: &Node) -> u64 {
        let (waves, _) = self.geometry(n.tc);
        match n.op {
            Opcode::Lod => REG_WINDOW + self.cost(n).saturating_sub(waves),
            Opcode::Dot | Opcode::Sum => waves + DOT_WINDOW,
            _ => REG_WINDOW,
        }
    }

    /// Cycle at which memory written by this store becomes readable,
    /// relative to the store's issue start.
    pub fn store_latency(&self, n: &Node) -> u64 {
        self.cost(n) + MEM_WINDOW
    }
}

/// Per-chain pipeline state (clean at every chain entry).
struct State {
    /// Readable-at cycle per value (monotone max, like
    /// `HazardChecker::write_reg`).
    vready: Vec<u64>,
    mem_ready: u64,
    /// Max over every pending window — the settle target.
    pending: u64,
}

impl State {
    fn new(nvals: u32) -> State {
        State {
            vready: vec![0; nvals as usize],
            mem_ready: 0,
            pending: 0,
        }
    }

    fn note_def(&mut self, v: super::ir::V, ready: u64) {
        let slot = &mut self.vready[v.0 as usize];
        if ready > *slot {
            *slot = ready;
        }
        self.pending = self.pending.max(ready);
    }

    fn note_store(&mut self, ready: u64) {
        self.mem_ready = self.mem_ready.max(ready);
        self.pending = self.pending.max(ready);
    }
}

enum Part {
    Seg(Vec<usize>),
    Barrier(usize),
}

struct Emit {
    slots: Vec<Slot>,
    starts: Vec<u64>,
    cycle: u64,
    nops: usize,
}

impl Emit {
    fn pad_until(&mut self, target: u64) {
        while self.cycle < target {
            self.slots.push(Slot::Pad);
            self.starts.push(self.cycle);
            self.cycle += 1;
            self.nops += 1;
        }
    }

    fn put(&mut self, idx: usize, cost: u64) {
        self.slots.push(Slot::Node(idx));
        self.starts.push(self.cycle);
        self.cycle += cost;
    }
}

/// Schedule the whole program under one strategy.
pub(crate) fn schedule(flat: &Flat, model: &CostModel, mode: SchedMode) -> Layout {
    let mut out = Emit {
        slots: Vec::new(),
        starts: Vec::new(),
        cycle: 0,
        nops: 0,
    };
    let mut parts: Vec<Part> = Vec::new();
    let mut seg: Vec<usize> = Vec::new();

    let flush_chain =
        |parts: &mut Vec<Part>, seg: &mut Vec<usize>, out: &mut Emit, term: Option<usize>| {
            if !seg.is_empty() {
                parts.push(Part::Seg(std::mem::take(seg)));
            }
            if parts.is_empty() && term.is_none() {
                return;
            }
            match mode {
                SchedMode::Fenced | SchedMode::Linear => {
                    emit_chain(parts, term, flat, model, mode, out);
                }
                SchedMode::List => {
                    // Emit both ways from the same start cycle, keep the
                    // shorter program (ties go to the readable in-order
                    // form). List never loses to Linear in the output.
                    let mut list = Emit {
                        slots: Vec::new(),
                        starts: Vec::new(),
                        cycle: out.cycle,
                        nops: 0,
                    };
                    emit_chain(parts, term, flat, model, SchedMode::List, &mut list);
                    let mut linear = Emit {
                        slots: Vec::new(),
                        starts: Vec::new(),
                        cycle: out.cycle,
                        nops: 0,
                    };
                    emit_chain(parts, term, flat, model, SchedMode::Linear, &mut linear);
                    let pick = if list.cycle < linear.cycle { list } else { linear };
                    out.slots.extend(pick.slots);
                    out.starts.extend(pick.starts);
                    out.cycle = pick.cycle;
                    out.nops += pick.nops;
                }
            }
            parts.clear();
        };

    for slot in &flat.order {
        match *slot {
            Slot::Label(l) => {
                // Settle straight-line state before the label so loop
                // bodies re-enter with a clean pipeline and the pads sit
                // outside the body.
                flush_chain(&mut parts, &mut seg, &mut out, None);
                out.slots.push(Slot::Label(l));
                out.starts.push(out.cycle);
            }
            Slot::Node(i) => {
                let n = &flat.nodes[i];
                if n.is_terminator() {
                    flush_chain(&mut parts, &mut seg, &mut out, Some(i));
                } else if n.is_barrier() {
                    if !seg.is_empty() {
                        parts.push(Part::Seg(std::mem::take(&mut seg)));
                    }
                    parts.push(Part::Barrier(i));
                } else {
                    seg.push(i);
                }
            }
            Slot::Pad => unreachable!("flatten emits no pads"),
        }
    }
    flush_chain(&mut parts, &mut seg, &mut out, None);

    let mut emitted_nodes = 0usize;
    let mut label_pos = vec![usize::MAX; flat.labels.len()];
    for (pos, s) in out.slots.iter().enumerate() {
        match *s {
            Slot::Node(_) => emitted_nodes += 1,
            Slot::Label(l) => label_pos[l] = pos,
            Slot::Pad => {}
        }
    }
    debug_assert_eq!(emitted_nodes, flat.nodes.len(), "every node must be emitted once");

    Layout {
        end_cycle: out.cycle,
        nops: out.nops,
        slots: out.slots,
        starts: out.starts,
        label_pos,
    }
}

/// Emit one chain: segments and predicate barriers, then the terminator.
fn emit_chain(
    parts: &[Part],
    term: Option<usize>,
    flat: &Flat,
    model: &CostModel,
    mode: SchedMode,
    out: &mut Emit,
) {
    let mut state = State::new(flat.nvals);
    for part in parts {
        match part {
            Part::Seg(idxs) => match mode {
                SchedMode::List => emit_seg_list(idxs, flat, model, &mut state, out),
                _ => emit_seg_in_order(idxs, flat, model, &mut state, out, mode),
            },
            Part::Barrier(i) => {
                let n = &flat.nodes[*i];
                let est = if mode == SchedMode::Fenced {
                    state.pending
                } else {
                    n.hazard_uses()
                        .iter()
                        .map(|v| state.vready[v.0 as usize])
                        .max()
                        .unwrap_or(0)
                };
                out.pad_until(est);
                out.put(*i, model.cost(n));
            }
        }
    }
    match term {
        Some(t) => {
            let n = &flat.nodes[t];
            // Settle before every control transfer (the hazard model's
            // linear-time assumption breaks across one): JMP/JSR/RTS/LOOP.
            // STOP drains the pipeline by itself — nothing reads after it.
            if n.op != Opcode::Stop {
                out.pad_until(state.pending);
            }
            out.put(t, model.cost(n));
        }
        None => {
            // Fall-through into a label (or end of program): settle so the
            // next chain starts clean.
            out.pad_until(state.pending);
        }
    }
}

/// Original order with per-dependence padding (`Linear`) or a full settle
/// before every instruction (`Fenced`).
fn emit_seg_in_order(
    idxs: &[usize],
    flat: &Flat,
    model: &CostModel,
    state: &mut State,
    out: &mut Emit,
    mode: SchedMode,
) {
    for &i in idxs {
        let n = &flat.nodes[i];
        let est = if mode == SchedMode::Fenced {
            state.pending
        } else {
            let mut est = n
                .hazard_uses()
                .iter()
                .map(|v| state.vready[v.0 as usize])
                .max()
                .unwrap_or(0);
            if n.op == Opcode::Lod {
                est = est.max(state.mem_ready);
            }
            est
        };
        out.pad_until(est);
        apply(n, i, model, state, out);
    }
}

/// Emit a node and record its hazard effects.
fn apply(n: &Node, idx: usize, model: &CostModel, state: &mut State, out: &mut Emit) {
    let start = out.cycle;
    let cost = model.cost(n);
    out.put(idx, cost);
    if let Some(d) = n.def {
        state.note_def(d, start + model.def_window(n));
    }
    if n.op == Opcode::Sto {
        state.note_store(start + model.store_latency(n));
    }
}

/// Dependence-graph list scheduling of one segment.
///
/// Edges carry the latencies the machine enforces:
/// - register RAW: writer's visibility window,
/// - memory RAW (store→load): store charge + `MEM_WINDOW`,
/// - register WAR/WAW, memory WAR/WAW (store↔store, load→store) and
///   INIT↔INIT sequencer order: pure ordering (latency 0) — sequential
///   issue makes order sufficient for these,
/// and carried-in constraints from earlier segments of the chain arrive
/// through `state` (the machine's windows are monotone maxima across
/// defs, so the carried value applies even when the segment redefines).
fn emit_seg_list(
    idxs: &[usize],
    flat: &Flat,
    model: &CostModel,
    state: &mut State,
    out: &mut Emit,
) {
    use std::collections::HashMap;

    let n = idxs.len();
    let mut preds: Vec<Vec<(usize, u64)>> = vec![Vec::new(); n];
    let mut succs: Vec<Vec<(usize, u64)>> = vec![Vec::new(); n];
    let mut base: Vec<u64> = vec![0; n];

    {
        fn edge(
            from: usize,
            to: usize,
            lat: u64,
            preds: &mut [Vec<(usize, u64)>],
            succs: &mut [Vec<(usize, u64)>],
        ) {
            preds[to].push((from, lat));
            succs[from].push((to, lat));
        }
        let mut last_def: HashMap<u32, usize> = HashMap::new();
        let mut readers: HashMap<u32, Vec<usize>> = HashMap::new();
        let mut stores: Vec<usize> = Vec::new();
        let mut loads: Vec<usize> = Vec::new();
        let mut last_init: Option<usize> = None;

        for (k, &i) in idxs.iter().enumerate() {
            let node = &flat.nodes[i];
            for v in node.hazard_uses() {
                // Carried constraint always applies (monotone windows).
                base[k] = base[k].max(state.vready[v.0 as usize]);
                if let Some(&d) = last_def.get(&v.0) {
                    let lat = model.def_window(&flat.nodes[idxs[d]]);
                    edge(d, k, lat, &mut preds, &mut succs);
                }
                readers.entry(v.0).or_default().push(k);
            }
            match node.op {
                Opcode::Lod => {
                    base[k] = base[k].max(state.mem_ready);
                    for &s in &stores {
                        let lat = model.store_latency(&flat.nodes[idxs[s]]);
                        edge(s, k, lat, &mut preds, &mut succs);
                    }
                }
                Opcode::Sto => {
                    for &l in &loads {
                        edge(l, k, 0, &mut preds, &mut succs);
                    }
                    for &s in &stores {
                        edge(s, k, 0, &mut preds, &mut succs);
                    }
                }
                Opcode::Init => {
                    if let Some(p) = last_init {
                        edge(p, k, 0, &mut preds, &mut succs);
                    }
                    last_init = Some(k);
                }
                _ => {}
            }
            if let Some(d) = node.def {
                if let Some(&pd) = last_def.get(&d.0) {
                    edge(pd, k, 0, &mut preds, &mut succs); // WAW
                }
                if let Some(rs) = readers.remove(&d.0) {
                    for r in rs {
                        if r != k {
                            edge(r, k, 0, &mut preds, &mut succs); // WAR
                        }
                    }
                }
                last_def.insert(d.0, k);
            }
            match node.op {
                Opcode::Lod => loads.push(k),
                Opcode::Sto => stores.push(k),
                _ => {}
            }
        }
    }

    // Critical-path priority (edges only point forward in original order).
    let mut prio: Vec<u64> = vec![0; n];
    for k in (0..n).rev() {
        let down = succs[k].iter().map(|&(j, lat)| lat + prio[j]).max().unwrap_or(0);
        prio[k] = down.max(model.cost(&flat.nodes[idxs[k]]));
    }

    let mut unmet: Vec<usize> = preds.iter().map(Vec::len).collect();
    let mut start_of: Vec<u64> = vec![0; n];
    let mut emitted = vec![false; n];
    let mut remaining = n;
    while remaining > 0 {
        // Ready = all predecessors emitted; issuable = earliest start has
        // arrived. Among issuable nodes pick the longest critical path.
        let mut best: Option<(u64, std::cmp::Reverse<usize>, usize)> = None;
        for k in 0..n {
            if emitted[k] || unmet[k] != 0 {
                continue;
            }
            let mut est = base[k];
            for &(p, lat) in &preds[k] {
                est = est.max(start_of[p] + lat);
            }
            if est <= out.cycle {
                let key = (prio[k], std::cmp::Reverse(k), k);
                if best.map(|b| key > (b.0, b.1, b.2)).unwrap_or(true) {
                    best = Some(key);
                }
            }
        }
        match best {
            Some((_, _, k)) => {
                start_of[k] = out.cycle;
                apply(&flat.nodes[idxs[k]], idxs[k], model, state, out);
                emitted[k] = true;
                remaining -= 1;
                for &(j, _) in &succs[k] {
                    if !emitted[j] {
                        unmet[j] -= 1;
                    }
                }
            }
            None => {
                out.slots.push(Slot::Pad);
                out.starts.push(out.cycle);
                out.cycle += 1;
                out.nops += 1;
            }
        }
    }
}
