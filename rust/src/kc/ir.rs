//! The kernel compiler's instruction IR: typed operations over virtual
//! registers, emitted through [`KernelBuilder`].
//!
//! Values are SSA-ish: every operation returns a fresh [`V`]; the `_into`
//! variants redefine an existing value, which is how predicated merges
//! (both IF/ELSE arms writing the same destination) and loop-carried
//! updates (`bcol += 1` at a LOOP back-edge) are expressed. Physical
//! registers do not appear anywhere in the IR — the linear-scan allocator
//! (`kc::regalloc`) assigns them after scheduling.
//!
//! The builder records a flat item stream (labels + instructions) in
//! emission order. That order is the *semantic* order: the scheduler may
//! only apply reorderings that provably preserve it under the machine's
//! dependence rules (`kc::sched`).

use crate::isa::opcode::OperandShape;
use crate::isa::{CondCode, Opcode, TType, ThreadCtrl, WordLayout};
use crate::sim::config::MemoryMode;

/// A virtual register. Created (and only created) by builder emissions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct V(pub(crate) u32);

/// One IR instruction: a decoded-instruction shape with virtual registers
/// in the register fields.
#[derive(Debug, Clone)]
pub(crate) struct Node {
    pub op: Opcode,
    pub ttype: TType,
    pub tc: ThreadCtrl,
    /// Raw immediate (LDI value bits, memory offset, INIT count, IF
    /// condition code). Branch targets live in `target` until lowering.
    pub imm: u16,
    /// Destination value (register-writing ops only).
    pub def: Option<V>,
    /// ra-field value.
    pub ra: Option<V>,
    /// rb-field value (encoding; SUM encodes rb = ra but reads only ra).
    pub rb: Option<V>,
    /// rd-field value when the field is a *read* (STO's store data).
    pub rd_use: Option<V>,
    /// Branch target label (JMP/JSR/LOOP).
    pub target: Option<String>,
    /// Comments attached above this instruction in the listing.
    pub comments: Vec<String>,
}

impl Node {
    /// The machine's hazard-checker read set for this instruction,
    /// mirroring `Machine::step_plan` exactly: this is what the scheduler
    /// pads against, so it must not drift from `sim::machine`.
    pub fn hazard_uses(&self) -> Vec<V> {
        match self.op.operands() {
            OperandShape::RdRa => self.ra.into_iter().collect(),
            OperandShape::RdRaRb => {
                if self.op == Opcode::Sum {
                    // plan_dot reads rb only when !sum_only.
                    self.ra.into_iter().collect()
                } else {
                    self.ra.into_iter().chain(self.rb).collect()
                }
            }
            OperandShape::RaRb => self.ra.into_iter().chain(self.rb).collect(),
            OperandShape::RdMem => {
                // LOD reads ra; STO reads ra and the rd (data) field.
                self.ra.into_iter().chain(self.rd_use).collect()
            }
            _ => Vec::new(),
        }
    }

    /// Every value referenced (for liveness), defs included.
    pub fn all_values(&self) -> Vec<V> {
        self.def
            .into_iter()
            .chain(self.ra)
            .chain(self.rb)
            .chain(self.rd_use)
            .collect()
    }

    /// Chain terminators: control transfers after which linear cycle
    /// tracking cannot continue (STOP included — nothing follows it).
    pub fn is_terminator(&self) -> bool {
        matches!(
            self.op,
            Opcode::Jmp | Opcode::Jsr | Opcode::Rts | Opcode::Loop | Opcode::Stop
        )
    }

    /// Predicate barriers: scheduling may not move instructions across
    /// IF/ELSE/ENDIF (the write-enable gate changes), but hazard timing
    /// carries straight through them.
    pub fn is_barrier(&self) -> bool {
        matches!(self.op, Opcode::If | Opcode::Else | Opcode::EndIf)
    }
}

/// Flat builder output: labels interleaved with instructions.
#[derive(Debug, Clone)]
pub(crate) enum Item {
    Label(String),
    Node(Node),
}

/// Emission front-end for one kernel. See the module docs of [`crate::kc`]
/// for the pipeline this feeds.
pub struct KernelBuilder {
    pub(crate) name: String,
    pub(crate) threads: usize,
    pub(crate) layout: WordLayout,
    pub(crate) memory: MemoryMode,
    pub(crate) items: Vec<Item>,
    pub(crate) nvals: u32,
    tc: ThreadCtrl,
    pending_comments: Vec<String>,
}

impl KernelBuilder {
    pub fn new(
        name: &str,
        threads: usize,
        layout: WordLayout,
        memory: MemoryMode,
    ) -> KernelBuilder {
        assert!(
            threads >= 16 && threads % 16 == 0,
            "threads must be a positive multiple of 16"
        );
        KernelBuilder {
            name: name.to_string(),
            threads,
            layout,
            memory,
            items: Vec::new(),
            nvals: 0,
            tc: ThreadCtrl::FULL,
            pending_comments: Vec::new(),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Sticky thread-space selector for subsequent instructions (like the
    /// assembler's `.mode` directive).
    pub fn space(&mut self, tc: ThreadCtrl) -> &mut Self {
        self.tc = tc;
        self
    }

    /// Back to the full thread space.
    pub fn full(&mut self) -> &mut Self {
        self.space(ThreadCtrl::FULL)
    }

    /// Attach a comment above the next emitted instruction.
    pub fn comment(&mut self, text: &str) -> &mut Self {
        self.pending_comments.push(text.to_string());
        self
    }

    pub fn label(&mut self, name: &str) -> &mut Self {
        self.items.push(Item::Label(name.to_string()));
        self
    }

    fn fresh(&mut self) -> V {
        let v = V(self.nvals);
        self.nvals += 1;
        v
    }

    fn blank(&mut self, op: Opcode, ttype: TType) -> Node {
        Node {
            op,
            ttype,
            tc: self.tc,
            imm: 0,
            def: None,
            ra: None,
            rb: None,
            rd_use: None,
            target: None,
            comments: std::mem::take(&mut self.pending_comments),
        }
    }

    fn push(&mut self, node: Node) {
        self.items.push(Item::Node(node));
    }

    // -----------------------------------------------------------------
    // Value producers.
    // -----------------------------------------------------------------

    pub fn tdx(&mut self) -> V {
        let d = self.fresh();
        let mut n = self.blank(Opcode::TdX, TType::Int);
        n.def = Some(d);
        self.push(n);
        d
    }

    pub fn tdy(&mut self) -> V {
        let d = self.fresh();
        let mut n = self.blank(Opcode::TdY, TType::Int);
        n.def = Some(d);
        self.push(n);
        d
    }

    /// Load an immediate; the hardware sign-extends i16, so any value in
    /// [-32768, 65535] round-trips through the 16-bit field.
    pub fn ldi(&mut self, imm: i64) -> V {
        let d = self.fresh();
        self.ldi_into(d, imm);
        d
    }

    /// Load an immediate into the value held in `slot`, creating it on
    /// first use — the subroutine-parameter idiom: one value, redefined
    /// at every call site, read inside the callee.
    pub fn ldi_reuse(&mut self, slot: &mut Option<V>, imm: i64) -> V {
        match *slot {
            Some(v) => {
                self.ldi_into(v, imm);
                v
            }
            None => {
                let v = self.ldi(imm);
                *slot = Some(v);
                v
            }
        }
    }

    pub fn ldi_into(&mut self, dst: V, imm: i64) {
        assert!(
            (-32768..=65535).contains(&imm),
            "ldi immediate {imm} does not fit in 16 bits"
        );
        let mut n = self.blank(Opcode::Ldi, TType::Int);
        n.def = Some(dst);
        n.imm = imm as u16;
        self.push(n);
    }

    /// Unary ALU op (`NEG`/`ABS`/`NOT`/`CNOT`/`BVS`/`POP`/`FNEG`/`FABS`/
    /// `INVSQR`).
    pub fn op1(&mut self, op: Opcode, ttype: TType, a: V) -> V {
        let d = self.fresh();
        self.op1_into(d, op, ttype, a);
        d
    }

    pub fn op1_into(&mut self, dst: V, op: Opcode, ttype: TType, a: V) {
        debug_assert_eq!(op.operands(), OperandShape::RdRa, "{op} is not unary");
        let mut n = self.blank(op, ttype);
        n.def = Some(dst);
        n.ra = Some(a);
        self.push(n);
    }

    /// Binary ALU op.
    pub fn op2(&mut self, op: Opcode, ttype: TType, a: V, b: V) -> V {
        let d = self.fresh();
        self.op2_into(d, op, ttype, a, b);
        d
    }

    pub fn op2_into(&mut self, dst: V, op: Opcode, ttype: TType, a: V, b: V) {
        debug_assert_eq!(op.operands(), OperandShape::RdRaRb, "{op} is not binary");
        debug_assert!(
            !matches!(op, Opcode::Dot | Opcode::Sum),
            "use dot()/sum() for extension-core ops"
        );
        let mut n = self.blank(op, ttype);
        n.def = Some(dst);
        n.ra = Some(a);
        n.rb = Some(b);
        self.push(n);
    }

    // Convenience wrappers matching the benchmark kernels' idiom. The
    // TYPE choices reproduce what the assembler would infer from the
    // original hand-written sources, so the pretty-printed listing
    // reassembles to the identical program.

    pub fn add_u(&mut self, a: V, b: V) -> V {
        self.op2(Opcode::Add, TType::Uint, a, b)
    }

    pub fn add_u_into(&mut self, dst: V, a: V, b: V) {
        self.op2_into(dst, Opcode::Add, TType::Uint, a, b)
    }

    pub fn sub_u(&mut self, a: V, b: V) -> V {
        self.op2(Opcode::Sub, TType::Uint, a, b)
    }

    pub fn sub_u_into(&mut self, dst: V, a: V, b: V) {
        self.op2_into(dst, Opcode::Sub, TType::Uint, a, b)
    }

    pub fn shl_u(&mut self, a: V, b: V) -> V {
        self.op2(Opcode::Shl, TType::Uint, a, b)
    }

    pub fn shr_u(&mut self, a: V, b: V) -> V {
        self.op2(Opcode::Shr, TType::Uint, a, b)
    }

    pub fn min_u(&mut self, a: V, b: V) -> V {
        self.op2(Opcode::Min, TType::Uint, a, b)
    }

    pub fn max_u(&mut self, a: V, b: V) -> V {
        self.op2(Opcode::Max, TType::Uint, a, b)
    }

    /// Untyped logic ops carry the assembler's default `.i32`.
    pub fn and_i(&mut self, a: V, b: V) -> V {
        self.op2(Opcode::And, TType::Int, a, b)
    }

    pub fn or_i(&mut self, a: V, b: V) -> V {
        self.op2(Opcode::Or, TType::Int, a, b)
    }

    pub fn or_i_into(&mut self, dst: V, a: V, b: V) {
        self.op2_into(dst, Opcode::Or, TType::Int, a, b)
    }

    pub fn xor_i(&mut self, a: V, b: V) -> V {
        self.op2(Opcode::Xor, TType::Int, a, b)
    }

    pub fn bvs(&mut self, a: V) -> V {
        self.op1(Opcode::Bvs, TType::Int, a)
    }

    pub fn fadd(&mut self, a: V, b: V) -> V {
        self.op2(Opcode::FAdd, TType::Fp32, a, b)
    }

    pub fn fsub(&mut self, a: V, b: V) -> V {
        self.op2(Opcode::FSub, TType::Fp32, a, b)
    }

    pub fn fmul(&mut self, a: V, b: V) -> V {
        self.op2(Opcode::FMul, TType::Fp32, a, b)
    }

    pub fn fneg(&mut self, a: V) -> V {
        self.op1(Opcode::FNeg, TType::Fp32, a)
    }

    // -----------------------------------------------------------------
    // Memory.
    // -----------------------------------------------------------------

    pub fn lod(&mut self, addr: V, offset: usize) -> V {
        let d = self.fresh();
        self.lod_into(d, addr, offset);
        d
    }

    pub fn lod_into(&mut self, dst: V, addr: V, offset: usize) {
        assert!(offset <= 0xFFFF, "memory offset {offset} out of range");
        let mut n = self.blank(Opcode::Lod, TType::Int);
        n.def = Some(dst);
        n.ra = Some(addr);
        n.imm = offset as u16;
        self.push(n);
    }

    pub fn sto(&mut self, value: V, addr: V, offset: usize) {
        assert!(offset <= 0xFFFF, "memory offset {offset} out of range");
        let mut n = self.blank(Opcode::Sto, TType::Int);
        n.rd_use = Some(value);
        n.ra = Some(addr);
        n.imm = offset as u16;
        self.push(n);
    }

    // -----------------------------------------------------------------
    // Extension cores.
    // -----------------------------------------------------------------

    pub fn dot(&mut self, a: V, b: V) -> V {
        let d = self.fresh();
        let mut n = self.blank(Opcode::Dot, TType::Fp32);
        n.def = Some(d);
        n.ra = Some(a);
        n.rb = Some(b);
        self.push(n);
        d
    }

    /// SUM streams only ra; rb is encoded as ra (the kernels' idiom).
    pub fn sum(&mut self, a: V) -> V {
        let d = self.fresh();
        let mut n = self.blank(Opcode::Sum, TType::Fp32);
        n.def = Some(d);
        n.ra = Some(a);
        n.rb = Some(a);
        self.push(n);
        d
    }

    // -----------------------------------------------------------------
    // Predicates.
    // -----------------------------------------------------------------

    pub fn if_cc(&mut self, cc: CondCode, ttype: TType, a: V, b: V) -> &mut Self {
        let mut n = self.blank(Opcode::If, ttype);
        n.ra = Some(a);
        n.rb = Some(b);
        n.imm = cc.bits() as u16;
        self.push(n);
        self
    }

    pub fn else_(&mut self) -> &mut Self {
        let n = self.blank(Opcode::Else, TType::Int);
        self.push(n);
        self
    }

    pub fn endif(&mut self) -> &mut Self {
        let n = self.blank(Opcode::EndIf, TType::Int);
        self.push(n);
        self
    }

    // -----------------------------------------------------------------
    // Control flow.
    // -----------------------------------------------------------------

    pub fn init(&mut self, count: usize) -> &mut Self {
        assert!(count <= 0xFFFF, "loop count {count} out of range");
        let mut n = self.blank(Opcode::Init, TType::Int);
        n.imm = count as u16;
        self.push(n);
        self
    }

    fn branch(&mut self, op: Opcode, target: &str) {
        let mut n = self.blank(op, TType::Int);
        // Control transfers always issue over the sequencer, not a
        // thread subset; keep the encoding canonical.
        n.tc = ThreadCtrl::FULL;
        n.target = Some(target.to_string());
        self.push(n);
    }

    pub fn jmp(&mut self, target: &str) -> &mut Self {
        self.branch(Opcode::Jmp, target);
        self
    }

    pub fn jsr(&mut self, target: &str) -> &mut Self {
        self.branch(Opcode::Jsr, target);
        self
    }

    pub fn loop_(&mut self, target: &str) -> &mut Self {
        self.branch(Opcode::Loop, target);
        self
    }

    pub fn rts(&mut self) -> &mut Self {
        let mut n = self.blank(Opcode::Rts, TType::Int);
        n.tc = ThreadCtrl::FULL;
        self.push(n);
        self
    }

    pub fn stop(&mut self) -> &mut Self {
        let mut n = self.blank(Opcode::Stop, TType::Int);
        n.tc = ThreadCtrl::FULL;
        self.push(n);
        self
    }
}
