//! Linear-scan register allocation onto the configured `WordLayout`
//! register space.
//!
//! One assignment must be valid for *every* layout the compiler can emit
//! (list-scheduled, linear, fenced) so that the scheduled and the
//! schedule-disabled builds of a kernel are register-identical — that is
//! what lets the correctness tests compare the two runs' full register
//! files bit for bit. Live intervals are therefore computed per layout and
//! two values conflict if their intervals overlap in *any* of them.
//!
//! Interval construction is conservative in three ways beyond plain
//! first-ref/last-ref spans:
//!
//! - **Writer windows**: a value's interval extends past its last def
//!   until the def's hazard window has expired on that layout's timeline.
//!   The machine's `reg_ready` is per *physical* register and monotone, so
//!   reusing a register whose previous occupant's writeback is still in
//!   flight would manufacture a hazard the scheduler never modeled.
//! - **Back-edges**: a value that is *live into* a LOOP body from the
//!   previous iteration (its first reference inside `[header, branch]` is
//!   a read, or a predicated — non-killing — write) is extended to the
//!   branch. Values the body redefines before reading stay local, which is
//!   what keeps loop-body temporaries reusable.
//! - **Calls**: any interval spanning a JSR (or a forward JMP) is extended
//!   to the end of the program — the callee (or the code jumped over)
//!   executes *inside* the caller's live range even though it sits
//!   elsewhere in the address space.

use super::sched::{CostModel, Flat, Layout, Slot};
use crate::isa::Opcode;

/// Inclusive slot-position interval; `end == slots.len()` marks a value
/// pinned live to the end of the program.
#[derive(Debug, Clone, Copy)]
struct Interval {
    start: usize,
    end: usize,
    used: bool,
}

/// First thing a loop body does to a value: read it (live into the body
/// across the back edge) or overwrite it unconditionally (body-local).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Fate {
    LiveIn,
    Killed,
}

fn intervals(flat: &Flat, layout: &Layout, model: &CostModel) -> Vec<Interval> {
    let nv = flat.nvals as usize;
    let mut iv = vec![
        Interval {
            start: usize::MAX,
            end: 0,
            used: false
        };
        nv
    ];
    // Plain reference spans.
    for (pos, slot) in layout.slots.iter().enumerate() {
        if let Slot::Node(i) = *slot {
            for v in flat.nodes[i].all_values() {
                let e = &mut iv[v.0 as usize];
                e.start = e.start.min(pos);
                e.end = e.end.max(pos);
                e.used = true;
            }
        }
    }
    // Writer-window extension: busy until the first position whose issue
    // start is at or past the window expiry.
    for (pos, slot) in layout.slots.iter().enumerate() {
        if let Slot::Node(i) = *slot {
            let n = &flat.nodes[i];
            if let Some(d) = n.def {
                let expiry = layout.starts[pos] + model.def_window(n);
                let q = layout.starts.partition_point(|&s| s < expiry);
                let e = &mut iv[d.0 as usize];
                e.end = e.end.max(q.saturating_sub(1));
            }
        }
    }
    // Classify branches: back edges (target before branch) vs call-like
    // transfers (JSR anywhere, forward JMP).
    let mut call_positions = Vec::new();
    let mut back_edges = Vec::new();
    for (pos, slot) in layout.slots.iter().enumerate() {
        if let Slot::Node(i) = *slot {
            let n = &flat.nodes[i];
            if matches!(n.op, Opcode::Jsr | Opcode::Jmp | Opcode::Loop) {
                let target_pos = n.target.as_ref().and_then(|t| {
                    flat.labels
                        .iter()
                        .position(|l| l == t)
                        .map(|l| layout.label_pos[l])
                });
                match target_pos {
                    Some(q) if q < pos && n.op != Opcode::Jsr => back_edges.push((q, pos)),
                    _ => call_positions.push(pos),
                }
            }
        }
    }
    // Back-edge extension: values live into the body survive the branch.
    for &(header, branch) in &back_edges {
        let mut fate: Vec<Option<Fate>> = vec![None; nv];
        let mut pred_depth = 0usize;
        for slot in &layout.slots[header..=branch] {
            let Slot::Node(i) = *slot else { continue };
            let n = &flat.nodes[i];
            match n.op {
                Opcode::If => pred_depth += 1,
                Opcode::EndIf => pred_depth = pred_depth.saturating_sub(1),
                _ => {}
            }
            // Reads first (an `x = f(x, ...)` update reads the inflowing
            // value), then the write.
            for v in n.ra.into_iter().chain(n.rb).chain(n.rd_use) {
                fate[v.0 as usize].get_or_insert(Fate::LiveIn);
            }
            if let Some(d) = n.def {
                // A predicated write keeps the old value for masked-off
                // threads — it does not kill.
                let f = if pred_depth == 0 { Fate::Killed } else { Fate::LiveIn };
                fate[d.0 as usize].get_or_insert(f);
            }
        }
        for (v, f) in fate.iter().enumerate() {
            if *f == Some(Fate::LiveIn) {
                // The inflowing value must survive the whole body; if its
                // only def sits *after* the use (pure wrap-around), the
                // occupied range also reaches back to the header.
                iv[v].end = iv[v].end.max(branch);
                iv[v].start = iv[v].start.min(header);
            }
        }
    }
    // Call spans: live across a JSR (or a JMP, conservatively) means live
    // to the end — other code runs temporally inside the range.
    let end_of_program = layout.slots.len();
    for e in iv.iter_mut().filter(|e| e.used) {
        if call_positions.iter().any(|&p| e.start <= p && p <= e.end) {
            e.end = end_of_program;
        }
    }
    iv
}

/// `Some(true)` = a wholly before b, `Some(false)` = wholly after,
/// `None` = overlap.
fn relation(a: Interval, b: Interval) -> Option<bool> {
    if a.end < b.start {
        Some(true)
    } else if b.end < a.start {
        Some(false)
    } else {
        None
    }
}

/// Assign every value a physical register in `0..=max_reg`, such that two
/// values share one only when their intervals are disjoint in *every*
/// layout **and in the same order**. Order consistency matters beyond
/// plain non-interference: the machine's final register file is part of
/// the scheduled-vs-fenced bit-identity contract, and if reordering
/// swapped which sharer wrote a register last, the two builds would end
/// with different (dead but visible) register contents. Values are
/// visited in order of first position in the primary (scheduled) layout —
/// a classic linear scan with a cross-layout conflict test.
pub(crate) fn allocate(
    flat: &Flat,
    layouts: &[&Layout],
    model: &CostModel,
    max_reg: u8,
) -> Result<Vec<u8>, String> {
    let nv = flat.nvals as usize;
    let ivs: Vec<Vec<Interval>> = layouts.iter().map(|l| intervals(flat, l, model)).collect();

    let mut order: Vec<usize> = (0..nv).collect();
    order.sort_by_key(|&v| (ivs[0][v].start, v));

    let conflicts = |a: usize, b: usize| {
        let mut dir: Option<bool> = None;
        for iv in &ivs {
            if !(iv[a].used && iv[b].used) {
                continue;
            }
            match relation(iv[a], iv[b]) {
                None => return true,
                Some(d) => {
                    if *dir.get_or_insert(d) != d {
                        return true;
                    }
                }
            }
        }
        false
    };

    let mut assignment = vec![0u8; nv];
    let mut by_reg: Vec<Vec<usize>> = vec![Vec::new(); max_reg as usize + 1];
    for &v in &order {
        if !ivs[0][v].used {
            continue; // never emitted; any register (0) is fine
        }
        let mut placed = false;
        for (r, occupants) in by_reg.iter_mut().enumerate() {
            if occupants.iter().all(|&u| !conflicts(v, u)) {
                assignment[v] = r as u8;
                occupants.push(v);
                placed = true;
                break;
            }
        }
        if !placed {
            return Err(format!(
                "register pressure exceeds the {}-register space ({} live values)",
                max_reg as usize + 1,
                nv
            ));
        }
    }
    Ok(assignment)
}
