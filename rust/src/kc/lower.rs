//! Lowering: scheduled IR + register assignment → [`crate::asm::Program`]
//! directly (decoded instructions, encoded words, labels, issue plans) —
//! no string round-trip — plus a faithful assembly pretty-printer for
//! debugging and the CLI.
//!
//! Faithful means: reassembling the printed text reproduces the lowered
//! program word for word (`rust/tests/kc_schedule.rs` pins this), so the
//! legacy `Kernel::assemble`-from-text path and the direct program path
//! stay bit-identical.

use std::collections::BTreeMap;

use crate::asm::{Program, SourceLine};
use crate::isa::{Instr, WordLayout};
use crate::sim::plan;

use super::sched::{Flat, Layout, Slot};

pub(crate) fn lower(
    name: &str,
    threads: usize,
    flat: &Flat,
    layout: &Layout,
    assignment: &[u8],
    word_layout: WordLayout,
) -> Result<(Program, String), String> {
    // Instruction addresses: labels occupy no address.
    let mut labels: BTreeMap<String, usize> = BTreeMap::new();
    let mut addr = 0usize;
    for slot in &layout.slots {
        match *slot {
            Slot::Label(l) => {
                if labels.insert(flat.labels[l].clone(), addr).is_some() {
                    return Err(format!("duplicate label '{}'", flat.labels[l]));
                }
            }
            Slot::Node(_) | Slot::Pad => addr += 1,
        }
    }

    let mut instrs = Vec::with_capacity(addr);
    let mut words = Vec::with_capacity(addr);
    let mut source = Vec::with_capacity(addr);
    let mut asm = format!("; {name} — kc-scheduled eGPU assembly ({threads} threads)\n");
    fn put_line(asm: &mut String, text: &str, line_no: &mut usize) {
        asm.push_str(text);
        asm.push('\n');
        *line_no += 1;
    }
    let mut line_no = 2usize; // line 1 is the header comment

    let reg = |v: super::ir::V| assignment[v.0 as usize];
    for slot in &layout.slots {
        match *slot {
            Slot::Label(l) => {
                put_line(&mut asm, &format!("{}:", flat.labels[l]), &mut line_no);
            }
            Slot::Pad => {
                let i = Instr::nop();
                source.push(SourceLine {
                    line_no,
                    text: "nop".to_string(),
                });
                put_line(&mut asm, "    nop", &mut line_no);
                words.push(word_layout.encode(&i));
                instrs.push(i);
            }
            Slot::Node(ni) => {
                let n = &flat.nodes[ni];
                for c in &n.comments {
                    put_line(&mut asm, &format!("    ; {c}"), &mut line_no);
                }
                let mut i = Instr::new(n.op);
                i.ttype = n.ttype;
                i.tc = n.tc;
                i.imm = n.imm;
                if let Some(d) = n.def {
                    i.rd = reg(d);
                }
                if let Some(v) = n.rd_use {
                    i.rd = reg(v);
                }
                if let Some(a) = n.ra {
                    i.ra = reg(a);
                }
                if let Some(b) = n.rb {
                    i.rb = reg(b);
                }
                let text = if let Some(t) = &n.target {
                    let target = *labels
                        .get(t)
                        .ok_or_else(|| format!("undefined label '{t}'"))?;
                    if target > 0xFFFF {
                        return Err(format!("label '{t}' address {target} overflows"));
                    }
                    i.imm = target as u16;
                    // Print the symbolic name; it reassembles to the same
                    // address because the line structure is preserved.
                    format!("{} {t}", n.op.mnemonic())
                } else {
                    i.disasm()
                };
                source.push(SourceLine {
                    line_no,
                    text: text.clone(),
                });
                put_line(&mut asm, &format!("    {text}"), &mut line_no);
                words.push(word_layout.encode(&i));
                instrs.push(i);
            }
        }
    }

    let plans = plan::compile(&instrs).map_err(|e| format!("plan at pc {}: {}", e.pc, e.message))?;
    Ok((
        Program {
            instrs,
            words,
            labels,
            layout: word_layout,
            source,
            plans,
        },
        asm,
    ))
}
