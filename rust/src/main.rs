//! `egpu` — CLI for the eGPU soft-GPGPU reproduction.
//!
//! Subcommands map onto the paper's evaluation:
//!
//! - `egpu tables`            resource/Fmax models (Tables 1, 4, 5, 6)
//! - `egpu bench [NAME|all]`  §7 benchmark suite (Tables 7, 8)
//! - `egpu profile`           instruction-mix profiles (Figure 6)
//! - `egpu place [PRESET]`    Agilex sector placement (Figures 4, 5)
//! - `egpu run FILE.asm`      assemble + run a user program
//! - `egpu fleet`             batch mixed kernels over a heterogeneous fleet
//! - `egpu serve`             continuous serving with admission control
//! - `egpu synth`             synthesize a fleet under an Agilex area budget
//! - `egpu sched KERNEL`      kernel-compiler schedule listing + stats
//! - `egpu info`              configuration presets and artifact status

use std::process::ExitCode;

use egpu::api::{
    synthesize, ApiError, AreaBudget, Backend, FleetBuilder, Gpu, KernelSpec, Server,
    SynthOptions, DEFAULT_CYCLE_BUDGET,
};
use egpu::asm::assemble;
use egpu::harness::loadgen::{demo_requests, heavy_tail_requests, BurstSpec, LoadSpec};
use egpu::harness::{demo_job_io, demo_specs, suite, Rng, Table, Variant};
use egpu::isa::Group;
use egpu::kernels::Kernel;
use egpu::model::alu_model::TABLE6;
use egpu::model::cost::{ppa_metric, TABLE1_PUBLISHED};
use egpu::model::frequency::FrequencyReport;
use egpu::model::resources::ResourceReport;
use egpu::place;
use egpu::runtime::default_artifacts_dir;
use egpu::sim::config_json;
use egpu::sim::{EgpuConfig, MemoryMode, TraceStats};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = &args[1.min(args.len())..];
    let r = match cmd {
        "tables" => cmd_tables(),
        "bench" => cmd_bench(rest),
        "profile" => cmd_profile(),
        "place" => cmd_place(rest),
        "run" => cmd_run(rest),
        "fleet" => cmd_fleet(rest),
        "serve" => cmd_serve(rest),
        "synth" => cmd_synth(rest),
        "sched" => cmd_sched(rest),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => Err(format!("unknown command '{other}'\n{HELP}")),
    };
    match r {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("egpu: {e}");
            ExitCode::FAILURE
        }
    }
}

const HELP: &str = "\
egpu — statically and dynamically scalable soft GPGPU (paper reproduction)

USAGE: egpu <COMMAND> [ARGS]

COMMANDS:
  tables            print the resource/Fmax model tables (Tables 1, 4, 5, 6)
  bench [NAME|all]  run the benchmark suite and print Tables 7/8
                    (NAME: reduction, transpose, mmm, bitonic, fft)
  profile           print the Figure 6 instruction-mix profiles
  place [PRESET]    place a configuration into an Agilex sector (Figures 4/5)
  run FILE.asm [--threads N] [--qp] [--xla] [--max-cycles N] [--cores N]
               [--config CFG.json] [--trace-stats]
                    assemble and run a program, dumping stats;
                    --cores N runs it on every core of an N-core GpuArray
                    (one stream per core, parallel worker dispatch);
                    --config loads the device configuration from JSON
                    (overrides --qp); --trace-stats prints the superplan
                    compiler's trace coverage (trace count, mean trace
                    length, % of dynamic instructions executed fused)
  fleet [--configs a.json,b.json] [--jobs N] [--seq] [--trace-out FILE]
                    dispatch a mixed kernel batch across a heterogeneous
                    fleet (default: 2 x 771 MHz DP-full + 2 x 600 MHz
                    QP cores), printing per-job placement, per-core
                    utilization and kernel-cache statistics; --configs
                    loads the fleet from JSON files (each holding one
                    config or an array); --seq uses sequential dispatch;
                    --trace-out writes a Chrome trace-event JSON of the
                    batch in modeled bus cycles (chrome://tracing)
  serve [--configs a.json,b.json] [--requests N] [--qdepth N] [--batch N]
        [--linger-us N] [--deadline-us N] [--gap N] [--seed N] [--seq]
        [--trace-out FILE] [--report]
                    continuously serve a seeded request stream through a
                    bounded admission queue and deadline/priority batcher
                    over the fleet (default: the 2xDP + 2xQP mix),
                    printing throughput, shed rate, latency percentiles
                    (p50/p95/p99) and per-core utilization; --qdepth
                    bounds the queue (overflow sheds), --deadline-us
                    gives half the requests deadlines with that slack,
                    --gap sets the mean inter-arrival gap in bus cycles,
                    --seq uses sequential dispatch (bit-identical —
                    including the recorded trace, byte for byte);
                    --trace-out writes a Chrome trace-event JSON of the
                    serving run in modeled bus cycles; --report prints
                    the per-core occupancy/gap summary
  synth [--alms N] [--dsps N] [--m20ks N] [--requests N] [--seed N]
        [--beam N] [--jobs N] [--out FILE.json]
                    synthesize the best-serving fleet under an Agilex
                    area budget: enumerate the static configuration
                    space, keep what fits and places, then beam-search
                    fleet compositions scored by replaying a seeded
                    heavy-tail trace (SLO-met requests, then modeled
                    cost); prints rejected candidates with the placer's
                    reasons and the score against the homogeneous demo
                    baselines; --jobs scores each frontier wave on N
                    worker threads (bit-identical result at any N);
                    --out writes the winning fleet as JSON consumable
                    by serve/fleet --configs
  sched KERNEL [DIM]
                    print a kernel's list-scheduled listing and the
                    static schedule stats (fenced / padded / scheduled)
                    (KERNEL: reduction, reduction-dot, reduction-pred,
                    transpose, mmm, mmm-dot, bitonic, fft, fft4)
  info              list presets and artifact status
";

/// Flag-parsing helpers shared by `cmd_run`/`cmd_fleet`/`cmd_sched`/
/// `cmd_serve`: every numeric argument fails with a usage error naming
/// the flag and the offending value — never a panic and never a
/// silently-clamped default (`--jobs 0` is an error, not an empty run).
mod flags {
    /// The value following `args[*i]` (the flag itself); advances the
    /// cursor past it.
    pub fn value<'a>(args: &'a [String], i: &mut usize, flag: &str) -> Result<&'a str, String> {
        *i += 1;
        args.get(*i).map(String::as_str).ok_or_else(|| format!("{flag} needs a value"))
    }

    /// Parse a numeric string, naming what it was for on failure.
    pub fn parse<T: std::str::FromStr>(what: &str, v: &str) -> Result<T, String> {
        v.parse::<T>().map_err(|_| format!("{what}: '{v}' is not a valid number"))
    }

    /// Next value parsed as a number.
    pub fn num<T: std::str::FromStr>(
        args: &[String],
        i: &mut usize,
        flag: &str,
    ) -> Result<T, String> {
        parse(flag, value(args, i, flag)?)
    }

    /// Next value as a `usize` of at least 1.
    pub fn positive_usize(args: &[String], i: &mut usize, flag: &str) -> Result<usize, String> {
        match num::<usize>(args, i, flag)? {
            0 => Err(format!("{flag} must be at least 1")),
            n => Ok(n),
        }
    }

    /// Next value as a `u64` of at least 1.
    pub fn positive_u64(args: &[String], i: &mut usize, flag: &str) -> Result<u64, String> {
        match num::<u64>(args, i, flag)? {
            0 => Err(format!("{flag} must be at least 1")),
            n => Ok(n),
        }
    }
}

/// Load a [`FleetBuilder`] from comma-separated JSON config files
/// (each holding one config object or an array) — the `--configs`
/// loader shared by `cmd_fleet` and `cmd_serve`.
fn fleet_from_files(paths: &str) -> Result<FleetBuilder, String> {
    let mut builder = FleetBuilder::new();
    for path in paths.split(',') {
        let json = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let parsed = config_json::configs_from_json(&json).map_err(|e| format!("{path}: {e}"))?;
        for cfg in parsed {
            builder = builder.core(cfg);
        }
    }
    Ok(builder)
}

fn cmd_tables() -> Result<(), String> {
    // Table 1: PPA comparison.
    let mut t1 = Table::new("Table 1: Resource Comparison (PPA normalized to eGPU = 1)");
    t1.headers(["Architecture", "Config", "LUTs", "DSP", "FMax", "PPA", "Device"]);
    for row in TABLE1_PUBLISHED {
        t1.row([
            row.arch.to_string(),
            row.config.to_string(),
            format!("{}K", row.luts / 1000),
            row.dsps.to_string(),
            format!("{:.0}", row.fmax_mhz),
            format!("{:.0}", ppa_metric(row.luts as f64, row.dsps as f64, row.fmax_mhz)),
            row.device.to_string(),
        ]);
    }
    let e = ResourceReport::for_config(&EgpuConfig::table4_presets()[0]);
    t1.row([
        "eGPU".into(),
        "1SMx16SP".into(),
        format!("{}K", e.alms / 1000),
        e.dsps.to_string(),
        "771".into(),
        "1".into(),
        "Agilex".to_string(),
    ]);
    t1.print();
    println!();

    // Tables 4 and 5: fitting results from the resource/frequency model.
    for (title, presets) in [
        ("Table 4: Fitting Results - DP Memory", EgpuConfig::table4_presets()),
        ("Table 5: Fitting Results - QP Memory", EgpuConfig::table5_presets()),
    ] {
        let mut t = Table::new(title);
        t.headers([
            "Config", "ALU", "Shift", "Threads", "Regs", "Shared", "Pred", "ALM", "Regs(FF)",
            "DSP", "M20K", "Freq",
        ]);
        for cfg in presets {
            let r = ResourceReport::for_config(&cfg);
            let f = FrequencyReport::for_config(&cfg);
            t.row([
                cfg.name.clone(),
                cfg.alu_precision.to_string(),
                cfg.shift_precision.to_string(),
                cfg.threads.to_string(),
                cfg.regs_per_thread.to_string(),
                format!("{}KB", cfg.shared_kb),
                cfg.predicate_levels.to_string(),
                r.alms.to_string(),
                r.registers.to_string(),
                r.dsps.to_string(),
                r.m20ks.to_string(),
                format!("{:.0}/{:.0}", f.soft_mhz, f.core_mhz),
            ]);
        }
        t.print();
        println!();
    }

    // Table 6: integer-ALU breakdown.
    let mut t6 = Table::new("Table 6: Fitting Results - Integer ALU");
    t6.headers(["Prec", "Type", "ALM", "Registers"]);
    for a in TABLE6 {
        t6.row([
            a.precision.to_string(),
            a.class.name().to_string(),
            a.alms.to_string(),
            a.regs.to_string(),
        ]);
    }
    t6.print();
    Ok(())
}

fn parse_bench(name: &str) -> Result<Vec<suite::Benchmark>, String> {
    use suite::Benchmark::*;
    Ok(match name {
        "all" => suite::Benchmark::ALL.to_vec(),
        "reduction" => vec![Reduction],
        "transpose" => vec![Transpose],
        "mmm" => vec![Mmm],
        "bitonic" => vec![Bitonic],
        "fft" => vec![Fft],
        other => return Err(format!("unknown benchmark '{other}'")),
    })
}

fn cmd_bench(args: &[String]) -> Result<(), String> {
    let which = parse_bench(args.first().map(String::as_str).unwrap_or("all"))?;
    for b in which {
        let mut t = Table::new(format!("{} (Tables 7/8) — measured (paper)", b.name()));
        t.headers(["Dim", "Metric", "Nios", "eGPU-DP", "eGPU-QP", "eGPU-Dot"]);
        for &dim in b.dims() {
            let r = suite::run(b, dim);
            let meas = |v: Variant| -> Option<&suite::Measurement> {
                match v {
                    Variant::Nios => Some(&r.nios),
                    Variant::Dp => Some(&r.dp),
                    Variant::Qp => Some(&r.qp),
                    Variant::Dot => r.dot.as_ref(),
                }
            };
            let cycles = |v: Variant| -> String {
                match meas(v) {
                    None => "-".into(),
                    Some(m) => match suite::paper_cycles(b, dim, v) {
                        Some(p) => format!("{} ({p})", m.cycles),
                        None => format!("{}", m.cycles),
                    },
                }
            };
            let time = |v: Variant| {
                meas(v).map(|m| format!("{:.2}", m.time_us())).unwrap_or_else(|| "-".into())
            };
            let norm = |v: Variant| {
                r.normalized(v).map(|x| format!("{x:.2}")).unwrap_or_else(|| "-".into())
            };
            let vs = [Variant::Nios, Variant::Dp, Variant::Qp, Variant::Dot];
            let mut row = vec![dim.to_string(), "Cycles (paper)".into()];
            row.extend(vs.iter().map(|&v| cycles(v)));
            t.row(row);
            let mut row = vec![dim.to_string(), "Time(us)".into()];
            row.extend(vs.iter().map(|&v| time(v)));
            t.row(row);
            let mut row = vec![dim.to_string(), "Normalized".into()];
            row.extend(vs.iter().map(|&v| norm(v)));
            t.row(row);
        }
        t.print();
        println!();
    }
    Ok(())
}

fn cmd_profile() -> Result<(), String> {
    println!("Figure 6: proportion of execution cycles by instruction type (eGPU-DP)\n");
    for b in suite::Benchmark::ALL {
        for &dim in b.dims() {
            let r = suite::run(b, dim);
            let p = r.dp.profile.as_ref().unwrap();
            let mut bars = String::new();
            for g in Group::ALL {
                let f = p.cycle_fraction(g);
                if f > 0.005 {
                    bars.push_str(&format!("{} {:4.1}%  ", g.label(), f * 100.0));
                }
            }
            println!("{:<18} {:>4}: {bars}", b.name(), dim);
        }
    }
    Ok(())
}

fn cmd_place(args: &[String]) -> Result<(), String> {
    let presets = EgpuConfig::table4_presets();
    let name = args.first().map(String::as_str).unwrap_or("Large-DP-2");
    let cfg = presets
        .iter()
        .chain(EgpuConfig::table5_presets().iter())
        .find(|c| c.name == name)
        .cloned()
        .ok_or_else(|| format!("unknown preset '{name}' (try `egpu info`)"))?;
    let p = place::place(&cfg).map_err(|e| e.to_string())?;
    println!("{}", place::render::render(&p));
    println!("{}", place::render::stats(&p));
    println!("\nSingle-SP detail (Figure 5):\n{}", place::render::render_sp(&p, 0));
    Ok(())
}

fn cmd_run(args: &[String]) -> Result<(), String> {
    let mut file = None;
    let mut threads = None;
    let mut memory = MemoryMode::Dp;
    let mut use_xla = false;
    let mut max_cycles = DEFAULT_CYCLE_BUDGET;
    let mut cores = 1usize;
    let mut config_path: Option<String> = None;
    let mut trace_stats = false;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--config" => config_path = Some(flags::value(args, &mut i, "--config")?.to_string()),
            "--threads" => threads = Some(flags::num(args, &mut i, "--threads")?),
            "--max-cycles" => max_cycles = flags::num(args, &mut i, "--max-cycles")?,
            "--cores" => cores = flags::positive_usize(args, &mut i, "--cores")?,
            "--qp" => memory = MemoryMode::Qp,
            "--xla" => use_xla = true,
            "--trace-stats" => trace_stats = true,
            f if !f.starts_with('-') => file = Some(f.to_string()),
            other => return Err(format!("unknown flag '{other}'")),
        }
        i += 1;
    }
    let file = file.ok_or(
        "usage: egpu run FILE.asm [--threads N] [--qp] [--xla] [--max-cycles N] \
         [--cores N] [--config CFG.json] [--trace-stats]",
    )?;
    let src = std::fs::read_to_string(&file).map_err(|e| format!("{file}: {e}"))?;

    let cfg = match &config_path {
        Some(path) => {
            let json = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
            let cfg =
                config_json::config_from_json(&json).map_err(|e| format!("{path}: {e}"))?;
            // A config that validates but cannot be placed into an
            // Agilex sector is unusable hardware: refuse it with the
            // placer's reason instead of simulating a fiction.
            place::place(&cfg)
                .map_err(|e| format!("{path}: {} is not placeable — {e}", cfg.name))?;
            cfg
        }
        None => {
            let mut cfg = EgpuConfig::benchmark(memory, true);
            cfg.predicate_levels = 8;
            cfg
        }
    };
    let prog = assemble(&src, cfg.word_layout()).map_err(|e| format!("{file}: {e}"))?;
    println!(
        "assembled {} instructions ({} M20Ks of program store)",
        prog.len(),
        prog.instruction_m20ks()
    );

    let backend = if use_xla {
        Backend::Xla(default_artifacts_dir())
    } else {
        Backend::Native
    };

    if cores > 1 {
        return run_multi_core(&file, &src, &cfg, backend, threads, max_cycles, cores, trace_stats);
    }

    let mut gpu = Gpu::builder()
        .config(cfg.clone())
        .backend(backend)
        .build()
        .map_err(|e| match e {
            ApiError::Backend(_) => format!("{e} (run `make artifacts`)"),
            other => other.to_string(),
        })?;
    let mut launch = gpu.launch_program(file.as_str(), prog).max_cycles(max_cycles);
    if let Some(t) = threads {
        launch = launch.threads(t);
    }
    let report = match launch.run() {
        Ok(r) => r,
        // A cycle-limit stop keeps its progress: show it before failing.
        Err(ApiError::Sim(s)) if s.partial.is_some() => {
            let p = s.partial.as_deref().unwrap();
            println!(
                "stopped at the cycle budget: {} cycles, {} instructions, {} hazards",
                p.cycles, p.instructions, p.hazards
            );
            println!("\ninstruction mix so far (cycles):");
            print!("{}", p.profile.render());
            return Err(format!("pc {}: {}", s.pc, s.message));
        }
        Err(e) => return Err(e.to_string()),
    };
    let stats = &report.stats;
    println!(
        "cycles: {}   instructions: {}   time at {:.0} MHz: {:.2} us   hazards: {}",
        stats.cycles,
        stats.instructions,
        cfg.core_mhz(),
        stats.time_us(cfg.core_mhz()),
        stats.hazards
    );
    println!("\ninstruction mix (cycles):");
    print!("{}", stats.profile.render());
    if trace_stats {
        print_trace_stats(&gpu.machine().trace_stats());
    }
    Ok(())
}

/// Render [`TraceStats`] for `--trace-stats`.
fn print_trace_stats(ts: &TraceStats) {
    println!(
        "\nsuperplan traces: {}   fused pcs: {}/{}   mean trace length: {:.2}\n\
         dynamic instructions executed fused: {}/{} ({:.1}%)",
        ts.traces,
        ts.fused_pcs,
        ts.program_pcs,
        ts.mean_trace_len,
        ts.fused_retired,
        ts.retired,
        ts.dynamic_fused_pct()
    );
}

/// `egpu run --cores N`: the same program on every core of an N-core
/// `GpuArray`, one stream per core, dispatched on parallel workers.
#[allow(clippy::too_many_arguments)]
fn run_multi_core(
    file: &str,
    src: &str,
    cfg: &EgpuConfig,
    backend: Backend,
    threads: Option<usize>,
    max_cycles: u64,
    cores: usize,
    trace_stats: bool,
) -> Result<(), String> {
    let rt_threads = threads.unwrap_or(cfg.threads);
    let kernel = Kernel::from_asm(file, src, rt_threads, rt_threads);
    let mut array = Gpu::builder()
        .config(cfg.clone())
        .backend(backend)
        .build_array(cores)
        .map_err(|e| e.to_string())?;
    let wall = std::time::Instant::now();
    for _ in 0..cores {
        let s = array.stream();
        array
            .launch_on(&s, kernel.clone())
            .max_cycles(max_cycles)
            .submit();
    }
    let reports = array.sync().map_err(|e| e.to_string())?;
    let wall_ms = wall.elapsed().as_secs_f64() * 1e3;
    for r in &reports {
        println!(
            "core {}: {} cycles   {} instructions   hazards: {}",
            r.core, r.compute_cycles, r.stats.instructions, r.stats.hazards
        );
    }
    println!(
        "makespan: {} cycles ({:.2} us at {:.0} MHz)   wall-clock: {:.1} ms \
         across {cores} worker threads (parallel dispatch)",
        array.makespan(),
        array.makespan_us(),
        cfg.core_mhz(),
        wall_ms
    );
    if trace_stats {
        // Identical program on every core: core 0 speaks for the fleet.
        print_trace_stats(&array.coordinator().core_machine(0).trace_stats());
    }
    Ok(())
}

/// `egpu fleet`: batch a mixed kernel set across a heterogeneous fleet
/// and print placement, per-core utilization and cache statistics.
fn cmd_fleet(args: &[String]) -> Result<(), String> {
    let mut cfg_paths: Option<String> = None;
    let mut jobs = 8usize;
    let mut sequential = false;
    let mut trace_out: Option<String> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--configs" => cfg_paths = Some(flags::value(args, &mut i, "--configs")?.to_string()),
            "--jobs" => jobs = flags::positive_usize(args, &mut i, "--jobs")?,
            "--seq" => sequential = true,
            "--trace-out" => {
                trace_out = Some(flags::value(args, &mut i, "--trace-out")?.to_string())
            }
            other => return Err(format!("unknown flag '{other}'")),
        }
        i += 1;
    }

    // Default: the reference 2 × 771 MHz DP-full + 2 × 600 MHz QP mix.
    let builder = match cfg_paths {
        Some(paths) => fleet_from_files(&paths)?,
        None => FleetBuilder::demo_mixed(),
    };
    let mut fleet = builder.build().map_err(|e| e.to_string())?;
    if sequential {
        fleet.set_parallel(false);
    }
    if trace_out.is_some() {
        fleet.start_recording();
    }

    // A mixed batch: feature-hungry kernels (predicates, dot core) next
    // to kernels any core can run — the shared demo wiring, so the CLI,
    // bench and example stay in lockstep.
    let n = 64usize;
    let mut rng = Rng::new(0xF1EE7);
    let specs = demo_specs(n);
    for j in 0..jobs {
        let spec = specs[j % specs.len()];
        let (loads, unloads) = demo_job_io(&spec, &mut rng);
        let mut launch = fleet.launch_spec_any(spec).map_err(|e| e.to_string())?;
        for (base, data) in loads {
            launch = launch.input_words(base, data);
        }
        for (base, len) in unloads {
            launch = launch.output(base, len);
        }
        launch.submit();
    }
    let reports = fleet.sync().map_err(|e| e.to_string())?;

    let mut t = Table::new(format!(
        "Fleet placement — {} jobs over {} cores (bus at {:.0} MHz)",
        reports.len(),
        fleet.num_cores(),
        fleet.coordinator().bus_mhz(),
    ));
    t.headers(["job", "core", "config", "MHz", "cycles", "time(us)", "requires"]);
    for r in &reports {
        let cfg = &fleet.core_configs()[r.core];
        let mhz = fleet.coordinator().core_mhz(r.core);
        t.row([
            r.name.clone(),
            r.core.to_string(),
            cfg.name.clone(),
            format!("{mhz:.0}"),
            r.compute_cycles.to_string(),
            format!("{:.2}", r.compute_cycles as f64 / mhz),
            r.requires.to_string(),
        ]);
    }
    t.print();
    println!();

    let util = fleet.core_utilization();
    let mut t = Table::new("Per-core utilization");
    t.headers(["core", "config", "MHz", "jobs", "busy", "util"]);
    for c in 0..fleet.num_cores() {
        let placed = reports.iter().filter(|r| r.core == c).count();
        let busy: u64 = reports
            .iter()
            .filter(|r| r.core == c)
            .map(|r| r.end - r.start)
            .sum();
        t.row([
            c.to_string(),
            fleet.core_configs()[c].name.clone(),
            format!("{:.0}", fleet.coordinator().core_mhz(c)),
            placed.to_string(),
            busy.to_string(),
            format!("{:.1}%", util[c] * 100.0),
        ]);
    }
    t.print();

    let stats = fleet.kernel_cache().stats();
    let span_us = fleet.makespan_us();
    println!(
        "\nkernel cache: {} compiles, {} hits, {} entries \
         (one compile per kernel x config fingerprint)",
        stats.compiles, stats.hits, stats.entries
    );
    println!(
        "makespan: {} bus cycles ({span_us:.2} us) — {:.0} modeled jobs/s",
        fleet.makespan(),
        reports.len() as f64 / (span_us * 1e-6)
    );
    if let Some(path) = trace_out {
        let rec = fleet.recorder().expect("recording was started");
        std::fs::write(&path, rec.chrome_trace())
            .map_err(|e| format!("cannot write trace to '{path}': {e}"))?;
        println!("trace: {} events -> {path} (modeled bus cycles)", rec.len());
    }
    Ok(())
}

/// `egpu serve`: continuously serve a seeded request stream through
/// the admission queue + deadline batcher over a heterogeneous fleet,
/// printing throughput, shed rate and latency percentiles.
fn cmd_serve(args: &[String]) -> Result<(), String> {
    let mut cfg_paths: Option<String> = None;
    let mut requests = 40usize;
    let mut qdepth = 64usize;
    let mut batch = 8usize;
    let mut linger_us = 8u64;
    let mut deadline_us: Option<u64> = None;
    let mut gap = 2_000u64;
    let mut seed = 0x5EEDu64;
    let mut sequential = false;
    let mut trace_out: Option<String> = None;
    let mut occupancy = false;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--configs" => cfg_paths = Some(flags::value(args, &mut i, "--configs")?.to_string()),
            "--requests" => requests = flags::positive_usize(args, &mut i, "--requests")?,
            "--qdepth" => qdepth = flags::positive_usize(args, &mut i, "--qdepth")?,
            "--batch" => batch = flags::positive_usize(args, &mut i, "--batch")?,
            "--linger-us" => linger_us = flags::num(args, &mut i, "--linger-us")?,
            "--deadline-us" => {
                deadline_us = Some(flags::positive_u64(args, &mut i, "--deadline-us")?)
            }
            "--gap" => gap = flags::num(args, &mut i, "--gap")?,
            "--seed" => seed = flags::num(args, &mut i, "--seed")?,
            "--seq" => sequential = true,
            "--trace-out" => {
                trace_out = Some(flags::value(args, &mut i, "--trace-out")?.to_string())
            }
            "--report" => occupancy = true,
            other => return Err(format!("unknown flag '{other}'")),
        }
        i += 1;
    }

    let mut builder = Server::builder()
        .qdepth(qdepth)
        .max_batch(batch)
        .linger_us(linger_us)
        .sequential(sequential)
        .recording(trace_out.is_some() || occupancy);
    if let Some(paths) = cfg_paths {
        builder = builder.fleet(fleet_from_files(&paths)?);
    }
    let mut server = builder.build().map_err(|e| e.to_string())?;

    let trace = demo_requests(&LoadSpec {
        seed,
        requests,
        mean_gap: gap,
        dim: 64,
        deadline_slack: deadline_us.map(|us| server.us_to_cycles(us)),
    });
    let report = server.serve_slice(&trace).map_err(|e| e.to_string())?;
    let t = &report.telemetry;
    let mhz = server.bus_mhz();

    let mut lat = Table::new(format!(
        "Serving telemetry — {} served / {} shed of {} offered, {} batches (bus at {mhz:.0} MHz)",
        t.completed,
        t.shed,
        report.submitted(),
        t.batches,
    ));
    lat.headers(["latency (us)", "p50", "p95", "p99", "mean", "max"]);
    for (name, h) in [
        ("queue wait", &t.queue_wait),
        ("service", &t.service),
        ("end-to-end", &t.e2e),
    ] {
        lat.row([
            name.to_string(),
            format!("{:.2}", h.p50() as f64 / mhz),
            format!("{:.2}", h.p95() as f64 / mhz),
            format!("{:.2}", h.p99() as f64 / mhz),
            format!("{:.2}", h.mean() / mhz),
            format!("{:.2}", h.max() as f64 / mhz),
        ]);
    }
    lat.print();
    println!();

    let util = server.core_utilization();
    let mut tu = Table::new("Per-core utilization");
    tu.headers(["core", "config", "MHz", "requests", "util"]);
    for c in 0..server.num_cores() {
        tu.row([
            c.to_string(),
            server.fleet().core_configs()[c].name.clone(),
            format!("{:.0}", server.fleet().coordinator().core_mhz(c)),
            report.results.iter().filter(|r| r.core == c).count().to_string(),
            format!("{:.1}%", util[c] * 100.0),
        ]);
    }
    tu.print();

    let stats = server.cache_stats();
    println!(
        "\nkernel cache: {} compiles, {} hits ({} entries) — compile once, serve forever",
        stats.compiles, stats.hits, stats.entries
    );
    if t.shed > 0 {
        let full = report
            .shed
            .iter()
            .filter(|s| s.reason == egpu::serve::ShedReason::QueueFull)
            .count();
        println!(
            "shed: {} ({:.1}% of offered; {} queue-full, {} deadline-expired)",
            t.shed,
            100.0 * t.shed_rate(),
            full,
            report.shed.len() - full
        );
    }
    println!(
        "deadline misses among served: {}   peak queue depth: {} (bound {})",
        t.deadline_missed, t.peak_queue, qdepth
    );
    println!(
        "span: {:.2} us modeled — {:.0} requests/s sustained",
        server.cycles_to_us(t.span_cycles()),
        t.jobs_per_s(mhz)
    );

    let sp = server.superplan_stats();
    println!(
        "superplan cache: {} compiles, {} hits ({} entries) — one fused-trace \
         compile per (kernel, config, threads)",
        sp.compiles, sp.hits, sp.entries
    );
    // Trace export and occupancy cover the primary serving run (the
    // recorder keeps accumulating through the steady-state replay
    // below, but the file is written from the events recorded so far).
    // Both are functions of modeled time only: byte-identical between
    // --seq and parallel dispatch.
    if occupancy {
        let rec = server.recorder().expect("recording was started");
        println!("\n{}", rec.occupancy_report(server.num_cores()));
    }
    if let Some(path) = &trace_out {
        let rec = server.recorder().expect("recording was started");
        std::fs::write(path, rec.chrome_trace())
            .map_err(|e| format!("cannot write trace to '{path}': {e}"))?;
        println!("trace: {} events -> {path} (modeled bus cycles)", rec.len());
    }
    // Steady-state proof: replay the identical trace on the warmed
    // server (fresh timeline window, caches kept) and show nothing
    // recompiles. Every printed quantity here is deterministic between
    // --seq and parallel dispatch.
    server.reset_timeline();
    let kernel_compiles = server.cache_stats().compiles;
    let superplan_compiles = server.superplan_stats().compiles;
    let replay = server.serve_slice(&trace).map_err(|e| e.to_string())?;
    println!(
        "steady-state replay: {} superplan recompiles, {} kernel recompiles \
         over {} repeat requests",
        server.superplan_stats().compiles - superplan_compiles,
        server.cache_stats().compiles - kernel_compiles,
        replay.submitted()
    );
    Ok(())
}

/// `egpu synth`: synthesize the best-serving fleet under an Agilex
/// area budget, scored by replaying a seeded heavy-tail trace through
/// the serving runtime in modeled bus cycles. Deterministic: the same
/// flags always print the same fleet.
fn cmd_synth(args: &[String]) -> Result<(), String> {
    let mut budget = AreaBudget::demo();
    let mut requests = 24usize;
    let mut seed: Option<u64> = None;
    let mut beam = 2usize;
    let mut jobs = 1usize;
    let mut out: Option<String> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--alms" => budget.alms = flags::positive_u64(args, &mut i, "--alms")?,
            "--dsps" => budget.dsps = flags::positive_u64(args, &mut i, "--dsps")?,
            "--m20ks" => budget.m20ks = flags::positive_u64(args, &mut i, "--m20ks")?,
            "--requests" => requests = flags::positive_usize(args, &mut i, "--requests")?,
            "--seed" => seed = Some(flags::num(args, &mut i, "--seed")?),
            "--beam" => beam = flags::positive_usize(args, &mut i, "--beam")?,
            "--jobs" => jobs = flags::positive_usize(args, &mut i, "--jobs")?,
            "--out" => out = Some(flags::value(args, &mut i, "--out")?.to_string()),
            other => return Err(format!("unknown flag '{other}'")),
        }
        i += 1;
    }

    let mut burst = BurstSpec::demo(requests);
    if let Some(s) = seed {
        burst.seed = s;
    }
    let trace = heavy_tail_requests(&burst);
    let opts = SynthOptions {
        beam,
        jobs,
        ..SynthOptions::default()
    };
    let result = synthesize(&budget, &trace, &opts)?;

    if !result.rejected.is_empty() {
        println!("rejected candidates ({}):", result.rejected.len());
        for r in &result.rejected {
            println!("  {} — {}", r.name, r.reason);
        }
        println!();
    }

    let mut tf = Table::new(format!(
        "Synthesized fleet — {} of {} requests SLO-met, cost {} ALM-eq \
         ({} fleets scored)",
        result.score.slo_met, result.offered, result.score.cost, result.evaluated
    ));
    tf.headers(["core", "config", "MHz", "ALMs", "DSPs", "M20Ks"]);
    for (c, cfg) in result.fleet.iter().enumerate() {
        let r = ResourceReport::for_config(cfg);
        tf.row([
            c.to_string(),
            cfg.name.clone(),
            format!("{:.0}", cfg.core_mhz()),
            r.alms.to_string(),
            r.dsps.to_string(),
            r.m20ks.to_string(),
        ]);
    }
    tf.print();
    println!("budget: {}   used: {}", result.budget, result.usage);
    println!(
        "served: {} completed, {} shed, {} deadline-missed of {} offered",
        result.completed, result.shed, result.deadline_missed, result.offered
    );

    let mut tb = Table::new("Homogeneous demo-fleet baselines (same budget, same trace)");
    tb.headers(["baseline", "cores", "SLO-met", "cost", "note"]);
    for b in &result.baselines {
        tb.row([
            b.name.clone(),
            b.cores.to_string(),
            b.slo_met.to_string(),
            b.cost.to_string(),
            b.note.clone().unwrap_or_default(),
        ]);
    }
    tb.print();

    let json = result.fleet_json();
    match out {
        Some(path) => {
            std::fs::write(&path, &json).map_err(|e| format!("{path}: {e}"))?;
            println!("\nfleet written to {path} — serve it with `egpu serve --configs {path}`");
        }
        None => println!("\nfleet JSON (use --out FILE.json to save):\n{json}"),
    }
    Ok(())
}

/// `egpu sched KERNEL [DIM]`: print the compiler's scheduled listing and
/// the static-schedule statistics for one benchmark kernel.
fn cmd_sched(args: &[String]) -> Result<(), String> {
    let usage = "usage: egpu sched KERNEL [DIM]  (kernels: reduction, \
                 reduction-dot, reduction-pred, transpose, mmm, mmm-dot, \
                 bitonic, fft, fft4)";
    let name = args.first().map(String::as_str).ok_or(usage)?;
    let dim = match args.get(1) {
        Some(d) => Some(flags::parse::<usize>("DIM", d)?),
        None => None,
    };
    let n = dim.unwrap_or(64);
    // KernelSpec validates the generators' size constraints up front so
    // a bad DIM is a usage error, not a panic inside a generator assert.
    let spec = KernelSpec::parse(name, n)
        .ok_or_else(|| format!("unknown kernel '{name}'\n{usage}"))?;
    let kernel = spec.build(&KernelSpec::canonical_config())?;
    let stats = kernel
        .sched
        .as_ref()
        .ok_or("kernel carries no schedule statistics")?;
    print!("{}", kernel.asm);
    println!();
    let mut t = Table::new(format!(
        "Static schedule — {} ({} threads, emitted mode: {})",
        kernel.name,
        kernel.threads,
        stats.mode.name()
    ));
    t.headers(["metric", "fenced", "linear (padded)", "list (scheduled)"]);
    t.row([
        "NOPs".into(),
        stats.nops_fenced.to_string(),
        stats.nops_linear.to_string(),
        stats.nops_scheduled.to_string(),
    ]);
    t.row([
        "static cycles".into(),
        stats.static_cycles_fenced.to_string(),
        stats.static_cycles_linear.to_string(),
        stats.static_cycles_scheduled.to_string(),
    ]);
    t.print();
    println!(
        "\n{} instructions; {} delay-slot NOPs filled by the list scheduler \
         ({:.1}% static-cycle reduction vs in-order padding)",
        stats.instructions,
        stats.nops_filled(),
        100.0 * stats.static_reduction_vs_linear()
    );
    Ok(())
}

fn cmd_info() -> Result<(), String> {
    println!("configuration presets:");
    for c in EgpuConfig::table4_presets().iter().chain(EgpuConfig::table5_presets().iter()) {
        let r = ResourceReport::for_config(c);
        println!(
            "  {:<12} {} threads, {} regs/thread, {}KB shared, {} pred levels -> {} ALMs, {} DSP, {} M20K @ {:.0} MHz",
            c.name,
            c.threads,
            c.regs_per_thread,
            c.shared_kb,
            c.predicate_levels,
            r.alms,
            r.dsps,
            r.m20ks,
            c.core_mhz()
        );
    }
    let dir = default_artifacts_dir();
    println!("\nartifacts dir: {}", dir.display());
    println!(
        "artifacts built: {}",
        if dir.join("opmap.json").is_file() {
            "yes"
        } else {
            "no (run `make artifacts`)"
        }
    );
    Ok(())
}
