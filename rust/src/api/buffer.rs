//! Typed device buffers over the eGPU shared memory.
//!
//! The eGPU's single local data memory is 32-bit word addressed (§2); a
//! [`Buffer<T>`] is a typed window onto a word range, and the host moves
//! data through it with [`Gpu::upload`](super::Gpu::upload) /
//! [`Gpu::download`](super::Gpu::download), which account every word on
//! the external 32-bit bus. This subsumes the ad-hoc
//! `f32_bits`/`i32_bits` + `write_block` host paths.

use std::marker::PhantomData;

/// A host-visible element type with a defined 32-bit device encoding.
///
/// The eGPU datapath is typeless at rest — registers and shared memory
/// hold raw 32-bit words; FP32 and INT32 are interpretations chosen per
/// instruction (§4). `DeviceRepr` fixes the host-side encoding.
pub trait DeviceRepr: Copy {
    /// Type label used in diagnostics.
    const NAME: &'static str;

    fn to_word(self) -> u32;
    fn from_word(word: u32) -> Self;
}

impl DeviceRepr for f32 {
    const NAME: &'static str = "f32";

    fn to_word(self) -> u32 {
        self.to_bits()
    }

    fn from_word(word: u32) -> f32 {
        f32::from_bits(word)
    }
}

impl DeviceRepr for i32 {
    const NAME: &'static str = "i32";

    fn to_word(self) -> u32 {
        self as u32
    }

    fn from_word(word: u32) -> i32 {
        word as i32
    }
}

impl DeviceRepr for u32 {
    const NAME: &'static str = "u32";

    fn to_word(self) -> u32 {
        self
    }

    fn from_word(word: u32) -> u32 {
        word
    }
}

/// A typed range of device shared memory: `len` elements of `T` starting
/// at word address `base`. Buffers are plain handles — cheap to copy,
/// created by [`Gpu::alloc`](super::Gpu::alloc) /
/// [`Gpu::alloc_at`](super::Gpu::alloc_at), and only meaningful on the
/// device that created them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Buffer<T: DeviceRepr> {
    base: usize,
    len: usize,
    _elem: PhantomData<T>,
}

impl<T: DeviceRepr> Buffer<T> {
    pub(crate) fn new(base: usize, len: usize) -> Buffer<T> {
        Buffer {
            base,
            len,
            _elem: PhantomData,
        }
    }

    /// First word address of the buffer (kernels address this directly).
    pub fn base(&self) -> usize {
        self.base
    }

    /// Element (= word) count.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// One-past-the-end word address.
    pub fn end(&self) -> usize {
        self.base + self.len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repr_roundtrips() {
        assert_eq!(f32::from_word((-1.5f32).to_word()), -1.5);
        assert_eq!(i32::from_word((-7i32).to_word()), -7);
        assert_eq!(u32::from_word(0xDEADBEEFu32.to_word()), 0xDEADBEEF);
        // f32 NaN payloads and signed zero survive the trip bit-exactly.
        assert_eq!(f32::to_word(f32::from_word(0x7FC0_0001)), 0x7FC0_0001);
        assert_eq!((-0.0f32).to_word(), 0x8000_0000);
    }

    #[test]
    fn buffer_geometry() {
        let b: Buffer<f32> = Buffer::new(64, 32);
        assert_eq!(b.base(), 64);
        assert_eq!(b.len(), 32);
        assert_eq!(b.end(), 96);
        assert!(!b.is_empty());
    }
}
