//! Single-core device handle: immediate-mode launches with uniform bus
//! accounting.

use std::sync::Arc;

use crate::asm::{assemble, Program};
use crate::coordinator::{bus_fraction, DataBus, JobResult, DEFAULT_CYCLE_BUDGET};
use crate::kernels::{CacheStats, Kernel, KernelCache, KernelSpec};
use crate::obs::StatsSnapshot;
use crate::sim::config::{EgpuConfig, FeatureSet};
use crate::sim::{Machine, RunStats};

use super::buffer::{Buffer, DeviceRepr};
use super::{ApiError, GpuBuilder};

/// Direction of a host↔device transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BusDir {
    HostToDevice,
    DeviceToHost,
}

/// One transfer on the external 32-bit bus, on the device's serial
/// timeline (uploads, kernel runs and downloads do not overlap on a
/// single-core device: one host, one bus, one core).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BusEvent {
    pub dir: BusDir,
    /// First shared-memory word address touched.
    pub base: usize,
    /// Words moved (1 word per bus cycle, §7).
    pub words: usize,
    /// Start/end cycle on the device timeline.
    pub start: u64,
    pub end: u64,
}

/// A completed launch: the paper's core metric ([`RunStats::cycles`])
/// plus the launch's place on the bus/compute timeline. The same record
/// describes immediate launches on a [`Gpu`] and stream jobs on a
/// [`GpuArray`](super::GpuArray).
#[derive(Debug, Clone)]
pub struct LaunchReport {
    pub name: String,
    /// Core the launch ran on (always 0 on a single-core [`Gpu`]).
    pub core: usize,
    /// Stream the launch was submitted on ([`GpuArray`] only).
    pub stream: Option<u64>,
    /// What the program demanded of the configuration (the axes a
    /// fleet dispatcher routes on). Stream/fleet launches carry the
    /// full [`Job::requires`](crate::coordinator::Job::requires) value
    /// (kernel axes + thread count + DMA footprint); immediate [`Gpu`]
    /// launches fill the program-derived axes only — their transfers
    /// are separate calls, not attributes of the launch, so
    /// `min_shared_words` stays 0 and `min_threads` is 0 unless the
    /// builder set an explicit thread count.
    pub requires: FeatureSet,
    /// Kernel cycles (the paper's benchmark metric).
    pub compute_cycles: u64,
    /// Bus cycles attributed to this launch: on a [`Gpu`], all host
    /// transfers since the previous launch; on a [`GpuArray`], the job's
    /// load + unload DMA.
    pub bus_cycles: u64,
    /// Timeline interval on the device clock (bus acquisition → done).
    pub start: u64,
    pub end: u64,
    /// Full run statistics (profile, hazards, instruction count).
    pub stats: RunStats,
    /// Unloaded output blocks, in submission order ([`GpuArray`] only;
    /// a [`Gpu`] reads results back through typed buffers instead).
    pub outputs: Vec<Vec<u32>>,
}

impl LaunchReport {
    /// Fraction of end-to-end time spent on the bus (§7's 4.7% claim);
    /// 0 when nothing moved and nothing ran.
    pub fn bus_overhead(&self) -> f64 {
        bus_fraction(self.bus_cycles, self.compute_cycles)
    }

    /// Compute time in microseconds at the given core clock.
    pub fn time_us(&self, mhz: f64) -> f64 {
        self.stats.time_us(mhz)
    }

    /// Output block `i` as raw words.
    ///
    /// # Panics
    /// If the launch declared fewer than `i + 1` outputs — in
    /// particular, immediate [`Gpu`] launches have none; read results
    /// back with [`Gpu::download`] instead.
    pub fn output_words(&self, i: usize) -> &[u32] {
        self.outputs.get(i).unwrap_or_else(|| {
            panic!(
                "launch '{}' has {} output block(s), no index {i}; immediate \
                 Gpu launches return results via typed buffers (Gpu::download)",
                self.name,
                self.outputs.len()
            )
        })
    }

    /// Output block `i` decoded as `f32` (panics like [`Self::output_words`]).
    pub fn output_f32(&self, i: usize) -> Vec<f32> {
        self.output_words(i).iter().map(|&w| f32::from_bits(w)).collect()
    }

    /// Output block `i` decoded as `i32` (panics like [`Self::output_words`]).
    pub fn output_i32(&self, i: usize) -> Vec<i32> {
        self.output_words(i).iter().map(|&w| w as i32).collect()
    }
}

impl From<JobResult> for LaunchReport {
    fn from(r: JobResult) -> LaunchReport {
        LaunchReport {
            name: r.name,
            core: r.core,
            stream: r.stream,
            requires: r.requires,
            compute_cycles: r.compute_cycles,
            bus_cycles: r.bus_cycles,
            start: r.start,
            end: r.end,
            stats: r.stats,
            outputs: r.outputs,
        }
    }
}

/// A single eGPU core with host-side buffer management and immediate
/// (synchronous) launches. Built by [`GpuBuilder`]; for multi-core
/// stream submission see [`GpuArray`](super::GpuArray).
pub struct Gpu {
    machine: Machine,
    bus: DataBus,
    /// Serial device timeline: advances over uploads, runs, downloads.
    clock: u64,
    total_compute: u64,
    total_bus: u64,
    /// Bus cycles since the last launch (attributed to the next report).
    pending_bus: u64,
    timeline: Vec<BusEvent>,
    /// Bump allocator high-water mark over shared-memory words.
    alloc_top: usize,
    /// Kernel-specialization cache behind [`Gpu::launch_spec`]
    /// (shareable across devices via `GpuBuilder::kernel_cache`).
    cache: Arc<KernelCache>,
}

impl Gpu {
    /// Start configuring a device (static-scalability knobs).
    pub fn builder() -> GpuBuilder {
        GpuBuilder::new()
    }

    /// Start configuring a heterogeneous fleet (per-core configs).
    pub fn fleet() -> super::FleetBuilder {
        super::FleetBuilder::new()
    }

    /// Device with the given configuration on the native datapath.
    pub fn new(cfg: &EgpuConfig) -> Result<Gpu, ApiError> {
        Gpu::builder().config(cfg.clone()).build()
    }

    /// Wrap an already-constructed machine (e.g. one with a custom
    /// [`BlockExec`](crate::datapath::BlockExec) backend).
    pub fn from_machine(mut machine: Machine) -> Gpu {
        let bus = DataBus::new(machine.cfg.core_mhz());
        let cache = KernelCache::shared();
        machine.set_superplan_cache(Arc::clone(cache.superplans()));
        Gpu {
            machine,
            bus,
            clock: 0,
            total_compute: 0,
            total_bus: 0,
            pending_bus: 0,
            timeline: Vec::new(),
            alloc_top: 0,
            cache,
        }
    }

    /// Share a kernel-specialization cache with other devices (fleets,
    /// other `Gpu`s). Replaces the private per-device cache; the
    /// machine re-attaches to the new cache's superplan side so
    /// fused-trace sharing follows the kernel cache.
    pub fn set_kernel_cache(&mut self, cache: Arc<KernelCache>) {
        self.cache = cache;
        self.machine
            .set_superplan_cache(Arc::clone(self.cache.superplans()));
    }

    /// Superplan cache counters for this device's cache handle (shared
    /// totals when the cache is shared across devices).
    pub fn superplan_stats(&self) -> crate::sim::SuperplanCacheStats {
        self.stats_snapshot().superplan
    }

    /// This device's counters in the unified
    /// [`crate::obs::StatsSnapshot`] shape. Machine reuse and worker
    /// pools are fleet concepts, so those axes stay zero on a
    /// single-core device.
    pub fn stats_snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            cache: self.cache.stats(),
            superplan: self.cache.superplans().stats(),
            superplan_activity: self.machine.superplan_activity(),
            ..StatsSnapshot::default()
        }
    }

    /// This device's kernel-specialization cache.
    pub fn kernel_cache(&self) -> &Arc<KernelCache> {
        &self.cache
    }

    /// Kernel-cache counters (compiles/hits/entries): asserts the
    /// compile-once property of [`Gpu::launch_spec`] without going
    /// through the cache handle.
    pub fn cache_stats(&self) -> CacheStats {
        self.stats_snapshot().cache
    }

    pub fn config(&self) -> &EgpuConfig {
        &self.machine.cfg
    }

    /// Escape hatch: the underlying machine (register/shared inspection).
    pub fn machine(&self) -> &Machine {
        &self.machine
    }

    /// Escape hatch: mutable machine access (e.g. host-side register
    /// seeding). Transfers made this way bypass bus accounting.
    pub fn machine_mut(&mut self) -> &mut Machine {
        &mut self.machine
    }

    /// Unwrap the device back into its machine (legacy interop).
    pub fn into_machine(self) -> Machine {
        self.machine
    }

    // -----------------------------------------------------------------
    // Buffers.
    // -----------------------------------------------------------------

    /// Allocate `len` elements at the next free word address.
    pub fn alloc<T: DeviceRepr>(&mut self, len: usize) -> Result<Buffer<T>, ApiError> {
        let base = self.alloc_top;
        self.alloc_at(base, len)
    }

    /// Allocate `len` elements at a fixed word address (the paper's
    /// kernels address shared memory absolutely, e.g. the reduction
    /// writes its sum at word `n`).
    pub fn alloc_at<T: DeviceRepr>(
        &mut self,
        base: usize,
        len: usize,
    ) -> Result<Buffer<T>, ApiError> {
        let words = self.machine.shared().len();
        if base + len > words {
            return Err(ApiError::OutOfMemory {
                requested: base + len,
                available: words,
            });
        }
        self.alloc_top = self.alloc_top.max(base + len);
        Ok(Buffer::new(base, len))
    }

    // -----------------------------------------------------------------
    // Transfers (uniformly accounted on the 32-bit bus).
    // -----------------------------------------------------------------

    fn record_transfer(&mut self, dir: BusDir, base: usize, words: usize) {
        let cycles = self.bus.transfer_cycles(words);
        let start = self.clock;
        self.clock += cycles;
        self.total_bus += cycles;
        self.pending_bus += cycles;
        self.timeline.push(BusEvent {
            dir,
            base,
            words,
            start,
            end: self.clock,
        });
    }

    /// Upload typed host data into a buffer (length must match).
    pub fn upload<T: DeviceRepr>(
        &mut self,
        buf: &Buffer<T>,
        data: &[T],
    ) -> Result<(), ApiError> {
        if data.len() != buf.len() {
            return Err(ApiError::SizeMismatch {
                expected: buf.len(),
                got: data.len(),
            });
        }
        let words: Vec<u32> = data.iter().map(|&v| v.to_word()).collect();
        self.write_words(buf.base(), &words)
    }

    /// Download a buffer's contents as typed host data.
    pub fn download<T: DeviceRepr>(&mut self, buf: &Buffer<T>) -> Result<Vec<T>, ApiError> {
        let words = self.read_words(buf.base(), buf.len())?;
        Ok(words.into_iter().map(T::from_word).collect())
    }

    /// Upload raw words at a word address (untyped DMA).
    pub fn write_words(&mut self, base: usize, words: &[u32]) -> Result<(), ApiError> {
        let size = self.machine.shared().len();
        if base + words.len() > size {
            return Err(ApiError::OutOfMemory {
                requested: base + words.len(),
                available: size,
            });
        }
        self.machine.shared_mut().write_block(base, words);
        self.record_transfer(BusDir::HostToDevice, base, words.len());
        Ok(())
    }

    /// Download raw words from a word address (untyped DMA).
    pub fn read_words(&mut self, base: usize, len: usize) -> Result<Vec<u32>, ApiError> {
        let size = self.machine.shared().len();
        if base + len > size {
            return Err(ApiError::OutOfMemory {
                requested: base + len,
                available: size,
            });
        }
        let words = self.machine.shared().read_block(base, len).to_vec();
        self.record_transfer(BusDir::DeviceToHost, base, len);
        Ok(words)
    }

    /// Zero shared memory (host-side reset; not a bus transfer — the
    /// coordinator's fresh-job clear has the same cost model).
    pub fn clear_shared(&mut self) {
        self.machine.shared_mut().fill(0);
    }

    // -----------------------------------------------------------------
    // Launches.
    // -----------------------------------------------------------------

    fn launch_builder(&mut self, name: String, source: LaunchSource) -> LaunchBuilder<'_> {
        LaunchBuilder {
            name,
            source,
            threads: None,
            dim_x: None,
            max_cycles: DEFAULT_CYCLE_BUDGET,
            hazard_checking: None,
            setup: None,
            gpu: self,
        }
    }

    /// Launch a generated kernel: threads/dim_x default to the kernel's
    /// declared values. Compiled kernels carry their lowered program
    /// (issue plans attached) and skip the assembler entirely; the
    /// listing is only re-assembled when the device's word layout differs
    /// from the one the kernel was compiled for.
    pub fn launch(&mut self, kernel: &Kernel) -> LaunchBuilder<'_> {
        let source = match &kernel.program {
            Some(p) if p.layout == self.machine.cfg.word_layout() => {
                LaunchSource::Program(p.clone())
            }
            _ => LaunchSource::Asm(kernel.asm.clone()),
        };
        let mut b = self.launch_builder(kernel.name.clone(), source);
        b.threads = Some(kernel.threads);
        b.dim_x = Some(kernel.dim_x);
        b
    }

    /// Launch a kernel by *specification*: compiled-and-scheduled for
    /// this device's configuration through the kernel cache — once per
    /// `(spec, fingerprint)` however many times it is launched — rather
    /// than eagerly rebuilt per call.
    pub fn launch_spec(&mut self, spec: &KernelSpec) -> Result<LaunchBuilder<'_>, ApiError> {
        let kernel = self.cache.get(spec, &self.machine.cfg).map_err(ApiError::Assemble)?;
        Ok(self.launch(&kernel))
    }

    /// Launch eGPU assembly source. Threads/dim_x keep the machine's
    /// current values unless set on the builder.
    pub fn launch_asm(
        &mut self,
        name: impl Into<String>,
        src: impl Into<String>,
    ) -> LaunchBuilder<'_> {
        self.launch_builder(name.into(), LaunchSource::Asm(src.into()))
    }

    /// Launch an already-assembled program.
    pub fn launch_program(
        &mut self,
        name: impl Into<String>,
        prog: Program,
    ) -> LaunchBuilder<'_> {
        self.launch_builder(name.into(), LaunchSource::Program(prog))
    }

    // -----------------------------------------------------------------
    // Accounting.
    // -----------------------------------------------------------------

    /// Device timeline position (bus + compute cycles so far).
    pub fn elapsed_cycles(&self) -> u64 {
        self.clock
    }

    pub fn total_bus_cycles(&self) -> u64 {
        self.total_bus
    }

    pub fn total_compute_cycles(&self) -> u64 {
        self.total_compute
    }

    /// Lifetime bus overhead: bus / (bus + compute), 0 if idle.
    pub fn bus_overhead(&self) -> f64 {
        bus_fraction(self.total_bus, self.total_compute)
    }

    /// Every bus transfer so far, in device-timeline order.
    pub fn timeline(&self) -> &[BusEvent] {
        &self.timeline
    }
}

enum LaunchSource {
    Asm(String),
    Program(Program),
}

/// Per-launch (dynamic-scalability) knobs: runtime thread subset, TDx
/// grid shape, cycle budget, hazard checking. Created by
/// [`Gpu::launch`]/[`Gpu::launch_asm`]/[`Gpu::launch_program`];
/// consumed by [`LaunchBuilder::run`].
pub struct LaunchBuilder<'g> {
    gpu: &'g mut Gpu,
    name: String,
    source: LaunchSource,
    threads: Option<usize>,
    dim_x: Option<usize>,
    max_cycles: u64,
    hazard_checking: Option<bool>,
    setup: Option<Box<dyn FnOnce(&mut Machine)>>,
}

impl LaunchBuilder<'_> {
    /// Runtime thread count (§3.2: any multiple of 16 up to the
    /// configured maximum — the dynamic thread-space knob).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads);
        self
    }

    /// TDx grid x-dimension (TDx = tid % dim_x, TDy = tid / dim_x).
    pub fn dim_x(mut self, dim_x: usize) -> Self {
        self.dim_x = Some(dim_x);
        self
    }

    /// Cycle budget (defaults to [`DEFAULT_CYCLE_BUDGET`]).
    pub fn max_cycles(mut self, max_cycles: u64) -> Self {
        self.max_cycles = max_cycles;
        self
    }

    /// Toggle pipeline-hazard tracking (off = verified-program fast
    /// path). Persists on the device until toggled again.
    pub fn hazard_checking(mut self, on: bool) -> Self {
        self.hazard_checking = Some(on);
        self
    }

    /// Host-side machine setup run after program load (which resets
    /// architectural state) and immediately before execution — e.g.
    /// seeding thread registers. Outside bus accounting.
    pub fn setup(mut self, f: impl FnOnce(&mut Machine) + 'static) -> Self {
        self.setup = Some(Box::new(f));
        self
    }

    /// Assemble (if needed), load, and run to STOP.
    pub fn run(self) -> Result<LaunchReport, ApiError> {
        let LaunchBuilder {
            gpu,
            name,
            source,
            threads,
            dim_x,
            max_cycles,
            hazard_checking,
            setup,
        } = self;
        let prog = match source {
            LaunchSource::Program(p) => p,
            LaunchSource::Asm(src) => assemble(&src, gpu.machine.cfg.word_layout())
                .map_err(|e| ApiError::Assemble(format!("{name}: {e}")))?,
        };
        let mut requires = FeatureSet::required_by(prog.instrs.iter());
        requires.min_threads = threads.unwrap_or(0);
        gpu.machine.load_program(prog)?;
        if let Some(t) = threads {
            gpu.machine.set_threads(t)?;
        }
        if let Some(d) = dim_x {
            gpu.machine.set_dim_x(d)?;
        }
        if let Some(h) = hazard_checking {
            gpu.machine.set_hazard_checking(h);
        }
        if let Some(f) = setup {
            f(&mut gpu.machine);
        }
        let stats = gpu.machine.run(max_cycles)?;

        let bus_cycles = std::mem::take(&mut gpu.pending_bus);
        // Only transfers advance the clock between launches, so the
        // attributed bus phase is exactly the last `bus_cycles` ticks.
        let start = gpu.clock - bus_cycles;
        gpu.clock += stats.cycles;
        gpu.total_compute += stats.cycles;
        Ok(LaunchReport {
            name,
            core: 0,
            stream: None,
            requires,
            compute_cycles: stats.cycles,
            bus_cycles,
            start,
            end: gpu.clock,
            stats,
            outputs: Vec::new(),
        })
    }
}
