//! Multi-core stream submission: ordered-per-stream launches over the
//! [`Coordinator`] and its shared 32-bit data bus.
//!
//! A [`Stream`] is an ordered lane of work. Launches submitted on one
//! stream execute in submission order on one core (stream→core
//! affinity), so `chained` launches — the paper's §7 "multiple
//! algorithms to the same data" mode — have a well-defined home: the
//! core holding the stream's resident shared memory. Launches on
//! different streams spread across cores and overlap, with load/unload
//! DMA serialized on the single external bus.

use std::sync::Arc;

use crate::coordinator::{Coordinator, Job, ReuseStats};
use crate::kernels::{CacheStats, Kernel, KernelCache, KernelSpec};
use crate::obs::{Recorder, StatsSnapshot};
use crate::sim::config::EgpuConfig;
use crate::sim::{SuperplanActivity, SuperplanCacheStats};

use super::gpu::LaunchReport;
use super::ApiError;

/// An ordered submission lane on a [`GpuArray`]. Cheap handle; create
/// with [`GpuArray::stream`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Stream {
    id: u64,
}

impl Stream {
    pub fn id(&self) -> u64 {
        self.id
    }
}

/// An array of eGPU cores behind one data bus, with stream-ordered
/// submission. Homogeneous arrays come from
/// [`GpuBuilder::build_array`](super::GpuBuilder::build_array);
/// heterogeneous fleets (per-core configurations) from
/// [`FleetBuilder`](super::FleetBuilder).
pub struct GpuArray {
    coord: Coordinator,
    next_stream: u64,
}

impl GpuArray {
    pub(crate) fn new(cfg: EgpuConfig, cores: usize) -> Result<GpuArray, ApiError> {
        Ok(GpuArray {
            coord: Coordinator::new(cfg, cores).map_err(ApiError::Sim)?,
            next_stream: 0,
        })
    }

    pub(crate) fn fleet(
        cfgs: Vec<EgpuConfig>,
        cache: Option<Arc<KernelCache>>,
    ) -> Result<GpuArray, ApiError> {
        let mut coord = Coordinator::fleet(cfgs).map_err(ApiError::Sim)?;
        if let Some(cache) = cache {
            coord.set_kernel_cache(cache);
        }
        Ok(GpuArray {
            coord,
            next_stream: 0,
        })
    }

    /// First core's configuration (*the* configuration on a homogeneous
    /// array; see [`GpuArray::core_configs`] for a fleet).
    pub fn config(&self) -> &EgpuConfig {
        self.coord.config()
    }

    /// Every core's configuration, index = core id.
    pub fn core_configs(&self) -> &[EgpuConfig] {
        self.coord.configs()
    }

    pub fn num_cores(&self) -> usize {
        self.coord.num_cores()
    }

    /// The fleet's kernel-specialization cache (shared by
    /// [`GpuArray::launch_spec`] submissions).
    pub fn kernel_cache(&self) -> &Arc<KernelCache> {
        self.coord.kernel_cache()
    }

    /// Open a new stream.
    pub fn stream(&mut self) -> Stream {
        let id = self.next_stream;
        self.next_stream += 1;
        Stream { id }
    }

    /// Open a stream pinned to one core — per-stream *config* affinity
    /// on a heterogeneous fleet: every launch on the stream runs on
    /// that core's configuration, and a launch the core cannot satisfy
    /// fails at [`GpuArray::sync`] instead of silently migrating off
    /// the stream's resident data.
    pub fn stream_on_core(&mut self, core: usize) -> Result<Stream, ApiError> {
        let s = self.stream();
        self.coord.pin_stream(s.id, core).map_err(ApiError::Sim)?;
        Ok(s)
    }

    /// Fraction of the makespan each core spent occupied. Successive
    /// [`GpuArray::sync`] batches accumulate on one timeline; a fresh
    /// measurement window is an explicit [`GpuArray::reset_timeline`].
    pub fn core_utilization(&self) -> Vec<f64> {
        self.coord.core_utilization()
    }

    /// Kernel-cache counters (compiles/hits/entries): the fleet-level
    /// "compile once, serve forever" property, assertable in tests
    /// without reaching for the coordinator escape hatch.
    pub fn cache_stats(&self) -> CacheStats {
        self.stats_snapshot().cache
    }

    /// Every runtime cache/reuse/pool counter in one struct — the
    /// unified stats surface ([`crate::obs::StatsSnapshot`]); the
    /// per-counter getters below delegate to it.
    pub fn stats_snapshot(&self) -> StatsSnapshot {
        self.coord.stats_snapshot()
    }

    /// Attach (or detach) an observability recorder on the fleet's
    /// coordinator (see [`crate::obs::Recorder`]). Recording changes
    /// no modeled cycle or result.
    pub fn set_recorder(&mut self, recorder: Option<Arc<Recorder>>) {
        self.coord.set_recorder(recorder);
    }

    /// The attached recorder, if any.
    pub fn recorder(&self) -> Option<Arc<Recorder>> {
        self.coord.recorder()
    }

    /// Attach a fresh recorder if none is attached; returns the shared
    /// sink. Idempotent.
    pub fn start_recording(&mut self) -> Arc<Recorder> {
        self.coord.start_recording()
    }

    /// Machine-reuse counters (hits = launches that skipped assembly
    /// and `load_program` because their core's machine already held
    /// the kernel's program): the per-core "load once, serve forever"
    /// property, one level below [`GpuArray::cache_stats`]. In steady
    /// state every core reaches zero reallocation per kernel — repeat
    /// batches add only hits.
    pub fn machine_reuse_stats(&self) -> ReuseStats {
        self.stats_snapshot().reuse
    }

    /// Fleet-wide superplan cache counters (compiles/hits/entries),
    /// one level below [`GpuArray::machine_reuse_stats`]: each distinct
    /// (program, config fingerprint, threads) triple compiles its fused
    /// traces exactly once across the whole fleet.
    pub fn superplan_stats(&self) -> SuperplanCacheStats {
        self.stats_snapshot().superplan
    }

    /// Summed per-core superplan rebuild/fast-skip activity (see
    /// [`crate::sim::SuperplanActivity`]).
    pub fn superplan_activity(&self) -> SuperplanActivity {
        self.stats_snapshot().superplan_activity
    }

    /// Worker pools spawned by the coordinator (0 sequential-only, else
    /// 1 for its whole lifetime).
    pub fn pool_spawns(&self) -> u64 {
        self.stats_snapshot().pool_spawns
    }

    /// Worker threads revived after dying (0 in normal operation).
    pub fn pool_revives(&self) -> u64 {
        self.stats_snapshot().pool_revives
    }

    /// Advance the modeled timeline to `cycle` (an explicit idle gap;
    /// see [`Coordinator::advance_timeline_to`]).
    pub fn advance_timeline_to(&mut self, cycle: u64) {
        self.coord.advance_timeline_to(cycle);
    }

    /// Start a fresh accounting window at cycle 0 (explicit reset;
    /// see [`Coordinator::reset_timeline`]).
    pub fn reset_timeline(&mut self) {
        self.coord.reset_timeline();
    }

    /// Toggle parallel (worker-thread-per-core) dispatch for
    /// [`GpuArray::sync`]. On by default; the sequential reference path
    /// produces bit-identical reports and timelines — only wall-clock
    /// time differs (`rust/tests/coordinator_integration.rs`).
    pub fn set_parallel(&mut self, on: bool) {
        self.coord.set_parallel(on);
    }

    /// Build a launch on a stream (ordered after everything previously
    /// submitted on that stream, on the stream's core).
    pub fn launch_on(&mut self, stream: &Stream, kernel: Kernel) -> StreamLaunch<'_> {
        StreamLaunch {
            job: Job::new(kernel).on_stream(stream.id),
            array: self,
        }
    }

    /// Build an unordered launch (wall-clock earliest-completion
    /// placement among the cores that satisfy the kernel's
    /// requirements).
    pub fn launch(&mut self, kernel: Kernel) -> StreamLaunch<'_> {
        StreamLaunch {
            job: Job::new(kernel),
            array: self,
        }
    }

    /// Build a launch from a kernel *specification* on a stream: the
    /// kernel is compiled for whatever core the dispatcher places it
    /// on, through the shared cache — once per `(spec, fingerprint)`
    /// across all streams and batches.
    pub fn launch_spec(
        &mut self,
        stream: &Stream,
        spec: KernelSpec,
    ) -> Result<StreamLaunch<'_>, ApiError> {
        let job = self.coord.job_from_spec(spec).map_err(ApiError::Sim)?;
        Ok(StreamLaunch {
            job: job.on_stream(stream.id),
            array: self,
        })
    }

    /// Unordered [`GpuArray::launch_spec`] (requirement-filtered,
    /// wall-clock earliest-completion placement).
    pub fn launch_spec_any(&mut self, spec: KernelSpec) -> Result<StreamLaunch<'_>, ApiError> {
        let job = self.coord.job_from_spec(spec).map_err(ApiError::Sim)?;
        Ok(StreamLaunch { job, array: self })
    }

    /// Run every submitted launch to completion and return their
    /// reports, in submission order.
    pub fn sync(&mut self) -> Result<Vec<LaunchReport>, ApiError> {
        let results = self.coord.run_all().map_err(ApiError::Sim)?;
        Ok(results.into_iter().map(LaunchReport::from).collect())
    }

    /// Completion cycle of the last finishing core.
    pub fn makespan(&self) -> u64 {
        self.coord.makespan()
    }

    /// Makespan in microseconds at the configured core clock.
    pub fn makespan_us(&self) -> f64 {
        self.coord.makespan_us()
    }

    /// Escape hatch: the underlying coordinator.
    pub fn coordinator(&self) -> &Coordinator {
        &self.coord
    }
}

/// A launch being assembled for stream submission: inputs are DMA'd in
/// over the bus before the run, outputs DMA'd out after, both accounted
/// per §7. Consumed by [`StreamLaunch::submit`].
#[must_use = "a stream launch does nothing until .submit()"]
pub struct StreamLaunch<'a> {
    array: &'a mut GpuArray,
    job: Job,
}

impl StreamLaunch<'_> {
    /// DMA raw words into shared memory at `base` before the run.
    pub fn input_words(mut self, base: usize, words: Vec<u32>) -> Self {
        self.job = self.job.load(base, words);
        self
    }

    /// DMA `f32` data into shared memory at `base` before the run.
    pub fn input_f32(self, base: usize, data: &[f32]) -> Self {
        self.input_words(base, data.iter().map(|v| v.to_bits()).collect())
    }

    /// DMA `i32` data into shared memory at `base` before the run.
    pub fn input_i32(self, base: usize, data: &[i32]) -> Self {
        self.input_words(base, data.iter().map(|&v| v as u32).collect())
    }

    /// DMA `len` words out from `base` after the run (retrieved from
    /// [`LaunchReport::outputs`] in declaration order).
    pub fn output(mut self, base: usize, len: usize) -> Self {
        self.job = self.job.unload(base, len);
        self
    }

    /// Chain onto the stream's resident data: skip the input DMA and do
    /// not clear shared memory (§7: "there is no loading and unloading
    /// of data between different algorithms").
    ///
    /// [`GpuArray::sync`] errors if the stream has no previous launch,
    /// if other work has since displaced the stream's data from its
    /// core, or if the launch also declares inputs (they would be
    /// silently skipped).
    pub fn chained(mut self) -> Self {
        self.job = self.job.chained();
        self
    }

    /// Cycle budget (defaults to
    /// [`DEFAULT_CYCLE_BUDGET`](crate::coordinator::DEFAULT_CYCLE_BUDGET)).
    pub fn max_cycles(mut self, max_cycles: u64) -> Self {
        self.job = self.job.budget(max_cycles);
        self
    }

    /// Queue the launch. Nothing executes until
    /// [`GpuArray::sync`].
    pub fn submit(self) {
        self.array.coord.submit(self.job);
    }
}
