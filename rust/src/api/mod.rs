//! The unified eGPU runtime API: `Gpu` / `Stream` / `Launch`.
//!
//! The paper's two scalability axes map onto two moments in this API:
//!
//! - **Static scalability** (§3, §5) is everything chosen *before* the
//!   device exists: thread space, registers per thread, shared-memory
//!   size and DP/QP organization, integer-ALU class and precisions,
//!   predicate depth, extension cores, and the datapath backend. All of
//!   it lives on [`GpuBuilder`].
//! - **Dynamic scalability** (§3.1) is everything chosen *per launch*:
//!   the runtime thread count, the TDx grid shape, and the cycle budget.
//!   All of it lives on [`LaunchBuilder`].
//!
//! In between sit typed device buffers ([`Buffer`]) whose host↔device
//! transfers are uniformly accounted through the external 32-bit
//! [`DataBus`](crate::coordinator::DataBus) model (§2, §7 — "the loading
//! and unloading of which has to be managed externally"), and
//! [`Stream`]s, which order multi-core work and give `keep_data`
//! chaining a well-defined home (stream→core affinity) on a
//! [`GpuArray`].
//!
//! # Single core, immediate mode
//!
//! ```no_run
//! use egpu::api::Gpu;
//! use egpu::kernels::reduction;
//!
//! # fn main() -> Result<(), egpu::api::ApiError> {
//! let n = 64;
//! let mut gpu = Gpu::builder().shared_kb(128).build()?;
//! let input = gpu.alloc_at::<f32>(0, n)?;
//! let sum = gpu.alloc_at::<f32>(n, 1)?;
//! let data: Vec<f32> = (0..n).map(|i| i as f32).collect();
//! gpu.upload(&input, &data)?;
//! let report = gpu.launch(&reduction::reduction(n)).run()?;
//! let result = gpu.download(&sum)?[0];
//! println!("sum = {result} in {} cycles", report.compute_cycles);
//! # Ok(()) }
//! ```
//!
//! # Multi-core streams
//!
//! ```no_run
//! use egpu::api::Gpu;
//! use egpu::kernels::fft;
//!
//! # fn main() -> Result<(), egpu::api::ApiError> {
//! let mut array = Gpu::builder().shared_kb(128).build_array(4)?;
//! let s = array.stream();
//! let (re, im) = (vec![0f32; 64], vec![0f32; 64]);
//! let mut launch = array.launch_on(&s, fft::fft(64)).output(0, 128);
//! for (base, words) in fft::shared_init(&re, &im) {
//!     launch = launch.input_words(base, words);
//! }
//! launch.submit();
//! let reports = array.sync()?;
//! let spectrum = reports[0].output_f32(0);
//! # let _ = spectrum; Ok(()) }
//! ```
//!
//! The legacy surfaces remain as thin shims: `Kernel::run` is
//! implemented on top of [`Gpu`], and [`GpuArray`] is a typed veneer
//! over [`Coordinator`](crate::coordinator::Coordinator). Cycle counts
//! and results through either path are bit-identical (asserted by
//! `rust/tests/api_parity.rs`).
//!
//! # Continuous serving
//!
//! Above batch submission sits the serving runtime
//! ([`Server`]/[`ServerBuilder`], re-exported from [`crate::serve`]):
//! a stream of [`Request`]s through a bounded admission queue with
//! load-shedding, deadline/priority-aware batching, and latency
//! telemetry over a heterogeneous fleet built with [`Gpu::fleet`].
//! [`synthesize`] (re-exported from [`crate::synth`]) closes the loop
//! the other way: given an [`AreaBudget`] and a traffic trace, it
//! searches the static-configuration space for the fleet that serves
//! the most requests within their SLOs.

mod buffer;
mod gpu;
mod stream;

pub use buffer::{Buffer, DeviceRepr};
pub use gpu::{BusDir, BusEvent, Gpu, LaunchBuilder, LaunchReport};
pub use stream::{GpuArray, Stream, StreamLaunch};

pub use crate::coordinator::DEFAULT_CYCLE_BUDGET;
pub use crate::kernels::{CacheStats, KernelCache, KernelSpec};
pub use crate::obs::{EventKind, MetricsRegistry, Recorder, StatsSnapshot, TraceEvent};
pub use crate::serve::{
    BatchPolicy, Histogram, Request, RequestResult, ServeReport, Server, ServerBuilder,
    ShedReason, ShedRecord, Telemetry,
};
pub use crate::sim::config::FeatureSet;
pub use crate::sim::{SuperplanActivity, SuperplanCacheStats};
pub use crate::synth::{
    synthesize, AreaBudget, AreaUsage, BaselineScore, FleetScore, SynthOptions, SynthResult,
};

/// Unweighted mean of per-launch bus overheads (the [`LaunchReport`]
/// counterpart of
/// [`coordinator::average_bus_overhead`](crate::coordinator::average_bus_overhead)).
pub fn average_bus_overhead(reports: &[LaunchReport]) -> f64 {
    crate::coordinator::mean_overhead(reports.iter().map(LaunchReport::bus_overhead))
}

use std::fmt;
use std::path::PathBuf;
use std::sync::Arc;

use crate::datapath::xla::XlaDatapath;
use crate::sim::config::{ConfigError, EgpuConfig, IntAluClass, MemoryMode};
use crate::sim::{Machine, SimError};

/// Which datapath executes wavefront blocks (static-scalability knob:
/// the machine is identical either way, proven by the equivalence tests).
#[derive(Debug, Clone, Default)]
pub enum Backend {
    /// Bit-exact native rust lanes (default, fast).
    #[default]
    Native,
    /// AOT-compiled XLA artifacts through PJRT, rooted at the given
    /// artifacts directory (`make artifacts`).
    Xla(PathBuf),
}

/// Unified error type for the runtime API.
#[derive(Debug, Clone)]
pub enum ApiError {
    /// Invalid static configuration.
    Config(ConfigError),
    /// Simulation-layer error (load/run faults, annotated with the PC).
    Sim(SimError),
    /// Assembly of a kernel or source string failed.
    Assemble(String),
    /// Datapath backend could not be constructed.
    Backend(String),
    /// Device allocation exceeds shared memory.
    OutOfMemory { requested: usize, available: usize },
    /// Host slice length does not match the buffer length.
    SizeMismatch { expected: usize, got: usize },
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ApiError::Config(e) => write!(f, "{e}"),
            ApiError::Sim(e) => write!(f, "{e}"),
            ApiError::Assemble(m) => write!(f, "assembly failed: {m}"),
            ApiError::Backend(m) => write!(f, "datapath backend: {m}"),
            ApiError::OutOfMemory {
                requested,
                available,
            } => write!(
                f,
                "device allocation of {requested} words exceeds the {available} \
                 shared-memory words available"
            ),
            ApiError::SizeMismatch { expected, got } => write!(
                f,
                "host data length {got} does not match buffer length {expected}"
            ),
        }
    }
}

impl std::error::Error for ApiError {}

impl From<ConfigError> for ApiError {
    fn from(e: ConfigError) -> ApiError {
        ApiError::Config(e)
    }
}

impl From<SimError> for ApiError {
    fn from(e: SimError) -> ApiError {
        ApiError::Sim(e)
    }
}

impl From<ApiError> for SimError {
    /// Legacy shims (`Kernel::run`) surface API errors as `SimError`.
    fn from(e: ApiError) -> SimError {
        match e {
            ApiError::Sim(s) => s,
            other => SimError::new(0, other.to_string()),
        }
    }
}

/// Builder for [`Gpu`] devices and [`GpuArray`]s: every configuration-time
/// parameter the paper lists (§3, §5), starting from the base machine
/// (512 threads × 16 SPs, 32 regs/thread, 32 KB DP shared memory).
#[derive(Debug, Clone, Default)]
pub struct GpuBuilder {
    cfg: EgpuConfig,
    backend: Backend,
}

impl GpuBuilder {
    pub fn new() -> GpuBuilder {
        GpuBuilder::default()
    }

    /// Start from a complete configuration (e.g. a Table 4/5 preset or
    /// `EgpuConfig::benchmark`).
    pub fn config(mut self, cfg: EgpuConfig) -> GpuBuilder {
        self.cfg = cfg;
        self
    }

    /// Human label for the configuration.
    pub fn name(mut self, name: impl Into<String>) -> GpuBuilder {
        self.cfg.name = name.into();
        self
    }

    /// Maximum initialized threads (multiple of 16).
    pub fn threads(mut self, threads: usize) -> GpuBuilder {
        self.cfg.threads = threads;
        self
    }

    /// Registers per thread: 16, 32 or 64.
    pub fn regs_per_thread(mut self, regs: usize) -> GpuBuilder {
        self.cfg.regs_per_thread = regs;
        self
    }

    /// Shared-memory size in KB.
    pub fn shared_kb(mut self, kb: usize) -> GpuBuilder {
        self.cfg.shared_kb = kb;
        self
    }

    /// DP or QP shared-memory organization.
    pub fn memory(mut self, mode: MemoryMode) -> GpuBuilder {
        self.cfg.memory = mode;
        self
    }

    /// Integer-ALU precision: 16 or 32 bits.
    pub fn alu_precision(mut self, bits: u8) -> GpuBuilder {
        self.cfg.alu_precision = bits;
        self
    }

    /// Shift precision: 1, 16 or 32.
    pub fn shift_precision(mut self, bits: u8) -> GpuBuilder {
        self.cfg.shift_precision = bits;
        self
    }

    /// Integer-ALU feature class (Table 6).
    pub fn int_alu(mut self, class: IntAluClass) -> GpuBuilder {
        self.cfg.int_alu = class;
        self
    }

    /// Predicate nesting levels (0 = predicates not synthesized).
    pub fn predicate_levels(mut self, levels: usize) -> GpuBuilder {
        self.cfg.predicate_levels = levels;
        self
    }

    /// Dot-product extension core.
    pub fn dot_core(mut self, on: bool) -> GpuBuilder {
        self.cfg.dot_core = on;
        self
    }

    /// SFU (reciprocal square root) extension core.
    pub fn sfu(mut self, on: bool) -> GpuBuilder {
        self.cfg.sfu = on;
        self
    }

    /// Datapath backend (native rust lanes or the XLA artifacts).
    pub fn backend(mut self, backend: Backend) -> GpuBuilder {
        self.backend = backend;
        self
    }

    /// The configuration as built so far.
    pub fn as_config(&self) -> &EgpuConfig {
        &self.cfg
    }

    fn build_machine(&self) -> Result<Machine, ApiError> {
        match &self.backend {
            Backend::Native => Machine::new(self.cfg.clone()).map_err(ApiError::Sim),
            Backend::Xla(dir) => {
                let be = XlaDatapath::new(dir, self.cfg.wavefronts())
                    .map_err(ApiError::Backend)?;
                Machine::with_backend(self.cfg.clone(), Some(Box::new(be)))
                    .map_err(ApiError::Sim)
            }
        }
    }

    /// Build a single-core device handle.
    pub fn build(self) -> Result<Gpu, ApiError> {
        self.cfg.validate()?;
        let machine = self.build_machine()?;
        Ok(Gpu::from_machine(machine))
    }

    /// Build an `cores`-core array with stream-ordered submission.
    /// Streams currently run on the native datapath only.
    pub fn build_array(self, cores: usize) -> Result<GpuArray, ApiError> {
        if !matches!(self.backend, Backend::Native) {
            return Err(ApiError::Backend(
                "GpuArray streams support the native datapath only".into(),
            ));
        }
        self.cfg.validate()?;
        GpuArray::new(self.cfg, cores)
    }
}

/// Builder for a *heterogeneous* [`GpuArray`]: a fleet of cores with
/// per-core static configurations — the paper's deployment story
/// (Tables 4/5: many differently-configured instances on one fabric,
/// each closing timing at its own embedded limit). Jobs route onto
/// cores that satisfy their [`FeatureSet`] requirements, with
/// wall-clock-aware placement across the mixed 771/600 MHz clocks.
///
/// ```no_run
/// use egpu::api::FleetBuilder;
/// use egpu::sim::{EgpuConfig, MemoryMode};
///
/// # fn main() -> Result<(), egpu::api::ApiError> {
/// let fleet = FleetBuilder::new()
///     .cores(EgpuConfig::benchmark_predicated(MemoryMode::Dp), 2)
///     .cores(EgpuConfig::benchmark(MemoryMode::Qp, false), 2)
///     .build()?;
/// assert_eq!(fleet.num_cores(), 4);
/// # Ok(()) }
/// ```
#[derive(Debug, Clone, Default)]
pub struct FleetBuilder {
    cfgs: Vec<EgpuConfig>,
    cache: Option<Arc<crate::kernels::KernelCache>>,
}

impl FleetBuilder {
    pub fn new() -> FleetBuilder {
        FleetBuilder::default()
    }

    /// The reference mixed fleet used by `egpu fleet`, the perf bench's
    /// `fleet` section and `examples/fleet_serving.rs`: two
    /// fully-featured 771 MHz DP cores (predicates + dot core) and two
    /// plain 600 MHz QP cores — one definition so the three surfaces
    /// cannot drift.
    pub fn demo_mixed() -> FleetBuilder {
        let mut dp = EgpuConfig::benchmark(MemoryMode::Dp, true);
        dp.predicate_levels = 8;
        dp.name = "DP-771-full".into();
        let mut qp = EgpuConfig::benchmark(MemoryMode::Qp, false);
        qp.name = "QP-600-plain".into();
        FleetBuilder::new().cores(dp, 2).cores(qp, 2)
    }

    /// Append one core with the given configuration.
    pub fn core(mut self, cfg: EgpuConfig) -> FleetBuilder {
        self.cfgs.push(cfg);
        self
    }

    /// Append `n` cores sharing one configuration.
    pub fn cores(mut self, cfg: EgpuConfig, n: usize) -> FleetBuilder {
        self.cfgs.extend(vec![cfg; n]);
        self
    }

    /// Share a kernel-specialization cache with other devices (one
    /// compile per `(spec, fingerprint)` across all of them).
    pub fn kernel_cache(mut self, cache: Arc<crate::kernels::KernelCache>) -> FleetBuilder {
        self.cache = Some(cache);
        self
    }

    /// The per-core configurations added so far.
    pub fn as_configs(&self) -> &[EgpuConfig] {
        &self.cfgs
    }

    /// Validate every configuration and build the fleet (at least one
    /// core required).
    pub fn build(self) -> Result<GpuArray, ApiError> {
        for cfg in &self.cfgs {
            cfg.validate()?;
        }
        GpuArray::fleet(self.cfgs, self.cache)
    }
}
