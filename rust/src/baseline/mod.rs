//! Comparison baselines (paper §2, §7).
//!
//! - [`nios`] — a Nios II/e-class scalar soft-RISC instruction-set
//!   simulator with the paper's cycle yardstick (CPI ≈ 1.7 on most
//!   benchmarks, ≈ 3 where 32×32 multiplies dominate; 347 MHz at 1100
//!   ALMs + 3 DSPs). Every eGPU benchmark has a scalar twin in
//!   [`nios_kernels`] running on it.
//! - [`flexgrip`] — FlexGrip's published Table 7 numbers (the paper, like
//!   us, compares against published results rather than a rerun).

pub mod flexgrip;
pub mod nios;
pub mod nios_kernels;

pub use nios::{Nios, NiosProgram, NiosStats, NIOS_ALMS, NIOS_DSPS, NIOS_MHZ};
