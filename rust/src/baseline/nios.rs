//! Nios II/e-class scalar RISC ISS (paper §7's comparison processor).
//!
//! The paper uses Nios II/e as the yardstick: a mature, economy soft RISC
//! — unpipelined, one ALU, data in a word-addressed local memory. We
//! implement a minimal scalar RISC VM with a per-class cycle model
//! matching the paper's measured efficiency: "Most of the benchmarks
//! retired an instruction every 1.7 clock cycles, except for the
//! matrix-matrix multiplies and FFT, which required about 3 clocks,
//! because of the way that 32×32 multipliers were implemented." The FP32
//! arithmetic is replaced by INT32 exactly as the paper did for its Nios
//! runs.

/// Nios II/e resource cost (§7): 1100 ALMs + 3 DSPs → normalized 1400.
pub const NIOS_ALMS: u32 = 1100;
pub const NIOS_DSPS: u32 = 3;
/// Closed timing at 347 MHz (§7).
pub const NIOS_MHZ: f64 = 347.0;

/// Register names are plain indices 0..32; r0 is general-purpose here.
pub type Reg = u8;

/// The scalar instruction set (enough for the five benchmarks).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NInstr {
    /// rd ← imm
    Ldi(Reg, i32),
    /// rd ← ra op rb
    Add(Reg, Reg, Reg),
    Sub(Reg, Reg, Reg),
    Mul(Reg, Reg, Reg),
    And(Reg, Reg, Reg),
    Or(Reg, Reg, Reg),
    Xor(Reg, Reg, Reg),
    Shl(Reg, Reg, Reg),
    Shr(Reg, Reg, Reg),
    Sar(Reg, Reg, Reg),
    /// rd ← ra + imm
    AddI(Reg, Reg, i32),
    /// rd ← ra * imm
    MulI(Reg, Reg, i32),
    /// rd ← mem[ra + off]
    Ld(Reg, Reg, i32),
    /// mem[ra + off] ← rs
    St(Reg, Reg, i32),
    /// conditional branches (target = absolute instruction index)
    Beq(Reg, Reg, usize),
    Bne(Reg, Reg, usize),
    Blt(Reg, Reg, usize),
    Bge(Reg, Reg, usize),
    Jmp(usize),
    Halt,
}

/// Per-class cycle costs for the II/e-style core. ALU/branch-not-taken are
/// multi-cycle on the real II/e; these constants are calibrated so the
/// benchmark mixes land at the paper's CPI ≈ 1.7 (≈ 3 with multiplies).
const CYC_ALU: u64 = 1;
const CYC_MUL: u64 = 9; // serialized 32×32 multiply (§7: "about 3 clocks"
                        // CPI over the whole mix)
const CYC_MEM: u64 = 3;
const CYC_BRANCH: u64 = 2;
const CYC_BRANCH_TAKEN: u64 = 3;

/// An assembled scalar program.
#[derive(Debug, Clone, Default)]
pub struct NiosProgram {
    pub instrs: Vec<NInstr>,
}

#[derive(Debug, Clone)]
pub struct NiosStats {
    pub cycles: u64,
    pub instructions: u64,
}

impl NiosStats {
    pub fn cpi(&self) -> f64 {
        self.cycles as f64 / self.instructions.max(1) as f64
    }

    pub fn time_us(&self) -> f64 {
        self.cycles as f64 / NIOS_MHZ
    }
}

/// The scalar machine: 32 registers + word-addressed local memory.
pub struct Nios {
    pub regs: [i32; 32],
    pub mem: Vec<i32>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NiosError(pub String);

impl std::fmt::Display for NiosError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "nios: {}", self.0)
    }
}

impl std::error::Error for NiosError {}

impl Nios {
    pub fn new(mem_words: usize) -> Nios {
        Nios {
            regs: [0; 32],
            mem: vec![0; mem_words],
        }
    }

    fn addr(&self, base: Reg, off: i32) -> Result<usize, NiosError> {
        let a = self.regs[base as usize].wrapping_add(off);
        if a < 0 || a as usize >= self.mem.len() {
            return Err(NiosError(format!("address {a} outside local memory")));
        }
        Ok(a as usize)
    }

    /// Run to HALT; returns the cycle/instruction counts.
    pub fn run(&mut self, prog: &NiosProgram, max_cycles: u64) -> Result<NiosStats, NiosError> {
        let mut pc = 0usize;
        let mut cycles = 0u64;
        let mut instrs = 0u64;
        loop {
            let i = *prog
                .instrs
                .get(pc)
                .ok_or_else(|| NiosError(format!("pc {pc} out of program")))?;
            instrs += 1;
            let r = &mut self.regs;
            match i {
                NInstr::Ldi(d, v) => {
                    r[d as usize] = v;
                    cycles += CYC_ALU;
                    pc += 1;
                }
                NInstr::Add(d, a, b) => {
                    r[d as usize] = r[a as usize].wrapping_add(r[b as usize]);
                    cycles += CYC_ALU;
                    pc += 1;
                }
                NInstr::Sub(d, a, b) => {
                    r[d as usize] = r[a as usize].wrapping_sub(r[b as usize]);
                    cycles += CYC_ALU;
                    pc += 1;
                }
                NInstr::Mul(d, a, b) => {
                    r[d as usize] = r[a as usize].wrapping_mul(r[b as usize]);
                    cycles += CYC_MUL;
                    pc += 1;
                }
                NInstr::And(d, a, b) => {
                    r[d as usize] = r[a as usize] & r[b as usize];
                    cycles += CYC_ALU;
                    pc += 1;
                }
                NInstr::Or(d, a, b) => {
                    r[d as usize] = r[a as usize] | r[b as usize];
                    cycles += CYC_ALU;
                    pc += 1;
                }
                NInstr::Xor(d, a, b) => {
                    r[d as usize] = r[a as usize] ^ r[b as usize];
                    cycles += CYC_ALU;
                    pc += 1;
                }
                NInstr::Shl(d, a, b) => {
                    r[d as usize] = r[a as usize].wrapping_shl(r[b as usize] as u32 & 31);
                    cycles += CYC_ALU;
                    pc += 1;
                }
                NInstr::Shr(d, a, b) => {
                    r[d as usize] =
                        ((r[a as usize] as u32).wrapping_shr(r[b as usize] as u32 & 31)) as i32;
                    cycles += CYC_ALU;
                    pc += 1;
                }
                NInstr::Sar(d, a, b) => {
                    r[d as usize] = r[a as usize].wrapping_shr(r[b as usize] as u32 & 31);
                    cycles += CYC_ALU;
                    pc += 1;
                }
                NInstr::AddI(d, a, v) => {
                    r[d as usize] = r[a as usize].wrapping_add(v);
                    cycles += CYC_ALU;
                    pc += 1;
                }
                NInstr::MulI(d, a, v) => {
                    r[d as usize] = r[a as usize].wrapping_mul(v);
                    cycles += CYC_MUL;
                    pc += 1;
                }
                NInstr::Ld(d, a, off) => {
                    let ad = self.addr(a, off)?;
                    self.regs[d as usize] = self.mem[ad];
                    cycles += CYC_MEM;
                    pc += 1;
                }
                NInstr::St(s, a, off) => {
                    let ad = self.addr(a, off)?;
                    self.mem[ad] = self.regs[s as usize];
                    cycles += CYC_MEM;
                    pc += 1;
                }
                NInstr::Beq(a, b, t) => {
                    if r[a as usize] == r[b as usize] {
                        pc = t;
                        cycles += CYC_BRANCH_TAKEN;
                    } else {
                        pc += 1;
                        cycles += CYC_BRANCH;
                    }
                }
                NInstr::Bne(a, b, t) => {
                    if r[a as usize] != r[b as usize] {
                        pc = t;
                        cycles += CYC_BRANCH_TAKEN;
                    } else {
                        pc += 1;
                        cycles += CYC_BRANCH;
                    }
                }
                NInstr::Blt(a, b, t) => {
                    if r[a as usize] < r[b as usize] {
                        pc = t;
                        cycles += CYC_BRANCH_TAKEN;
                    } else {
                        pc += 1;
                        cycles += CYC_BRANCH;
                    }
                }
                NInstr::Bge(a, b, t) => {
                    if r[a as usize] >= r[b as usize] {
                        pc = t;
                        cycles += CYC_BRANCH_TAKEN;
                    } else {
                        pc += 1;
                        cycles += CYC_BRANCH;
                    }
                }
                NInstr::Jmp(t) => {
                    pc = t;
                    cycles += CYC_BRANCH_TAKEN;
                }
                NInstr::Halt => {
                    return Ok(NiosStats {
                        cycles,
                        instructions: instrs,
                    })
                }
            }
            if cycles > max_cycles {
                return Err(NiosError(format!("cycle limit {max_cycles} exceeded")));
            }
        }
    }
}

/// Program builder with forward-label support.
#[derive(Default)]
pub struct NiosAsm {
    instrs: Vec<NInstr>,
    fixups: Vec<(usize, String)>,
    labels: std::collections::BTreeMap<String, usize>,
}

impl NiosAsm {
    pub fn new() -> NiosAsm {
        NiosAsm::default()
    }

    pub fn emit(&mut self, i: NInstr) -> &mut Self {
        self.instrs.push(i);
        self
    }

    pub fn label(&mut self, name: &str) -> &mut Self {
        assert!(
            self.labels
                .insert(name.to_string(), self.instrs.len())
                .is_none(),
            "duplicate label {name}"
        );
        self
    }

    /// Emit a branch to a (possibly forward) label.
    pub fn branch(&mut self, make: impl Fn(usize) -> NInstr, target: &str) -> &mut Self {
        self.fixups.push((self.instrs.len(), target.to_string()));
        self.instrs.push(make(usize::MAX));
        self
    }

    pub fn finish(mut self) -> NiosProgram {
        for (at, label) in &self.fixups {
            let t = *self.labels.get(label).unwrap_or_else(|| {
                panic!("undefined label {label}");
            });
            self.instrs[*at] = match self.instrs[*at] {
                NInstr::Beq(a, b, _) => NInstr::Beq(a, b, t),
                NInstr::Bne(a, b, _) => NInstr::Bne(a, b, t),
                NInstr::Blt(a, b, _) => NInstr::Blt(a, b, t),
                NInstr::Bge(a, b, _) => NInstr::Bge(a, b, t),
                NInstr::Jmp(_) => NInstr::Jmp(t),
                other => panic!("fixup on non-branch {other:?}"),
            };
        }
        NiosProgram {
            instrs: self.instrs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use NInstr::*;

    #[test]
    fn arithmetic_and_memory() {
        let mut a = NiosAsm::new();
        a.emit(Ldi(1, 6))
            .emit(Ldi(2, 7))
            .emit(Mul(3, 1, 2))
            .emit(St(3, 0, 5))
            .emit(Ld(4, 0, 5))
            .emit(Halt);
        let mut m = Nios::new(16);
        let s = m.run(&a.finish(), 1000).unwrap();
        assert_eq!(m.regs[4], 42);
        assert_eq!(m.mem[5], 42);
        assert_eq!(s.instructions, 6);
    }

    #[test]
    fn loop_with_labels() {
        // sum 1..=10
        let mut a = NiosAsm::new();
        a.emit(Ldi(1, 0)) // acc
            .emit(Ldi(2, 1)) // i
            .emit(Ldi(3, 11)) // bound
            .label("top")
            .emit(Add(1, 1, 2))
            .emit(AddI(2, 2, 1))
            .branch(|t| Blt(2, 3, t), "top")
            .emit(Halt);
        let mut m = Nios::new(4);
        m.run(&a.finish(), 10_000).unwrap();
        assert_eq!(m.regs[1], 55);
    }

    #[test]
    fn forward_branch() {
        let mut a = NiosAsm::new();
        a.emit(Ldi(1, 1))
            .branch(|t| Bne(1, 0, t), "skip")
            .emit(Ldi(2, 99)) // skipped
            .label("skip")
            .emit(Ldi(3, 7))
            .emit(Halt);
        let mut m = Nios::new(4);
        m.run(&a.finish(), 1000).unwrap();
        assert_eq!(m.regs[2], 0);
        assert_eq!(m.regs[3], 7);
    }

    #[test]
    fn cycle_model_classes() {
        let mut a = NiosAsm::new();
        a.emit(Ldi(1, 1)).emit(Mul(2, 1, 1)).emit(Ld(3, 0, 0)).emit(Halt);
        let mut m = Nios::new(4);
        let s = m.run(&a.finish(), 1000).unwrap();
        assert_eq!(s.cycles, CYC_ALU + CYC_MUL + CYC_MEM);
    }

    #[test]
    fn memory_fault() {
        let mut a = NiosAsm::new();
        a.emit(Ld(1, 0, 100)).emit(Halt);
        let mut m = Nios::new(4);
        assert!(m.run(&a.finish(), 1000).is_err());
    }

    #[test]
    fn shift_semantics() {
        let mut a = NiosAsm::new();
        a.emit(Ldi(1, -16))
            .emit(Ldi(2, 2))
            .emit(Shr(3, 1, 2))
            .emit(Sar(4, 1, 2))
            .emit(Shl(5, 2, 2))
            .emit(Halt);
        let mut m = Nios::new(4);
        m.run(&a.finish(), 1000).unwrap();
        assert_eq!(m.regs[3] as u32, 0x3FFFFFFC);
        assert_eq!(m.regs[4], -4);
        assert_eq!(m.regs[5], 8);
    }

    #[test]
    fn cycle_limit() {
        let mut a = NiosAsm::new();
        a.label("x").branch(|t| NInstr::Jmp(t), "x");
        let mut m = Nios::new(4);
        assert!(m.run(&a.finish(), 100).is_err());
    }
}
