//! FlexGrip comparison data (paper §7, Table 7).
//!
//! The paper compares against FlexGrip's *published* MMM results ("We
//! report the comparison to FlexGrip only for the MMM, as the larger
//! dataset size would be less affected by any overheads") — it does not
//! rerun FlexGrip. We do the same: the published cycle counts at
//! FlexGrip's 100 MHz clock, plus helpers for the ratio rows.

/// FlexGrip clock (Virtex-6, §2).
pub const FLEXGRIP_MHZ: f64 = 100.0;

/// Published FlexGrip MMM results (Table 7): (n, cycles).
pub const MMM_CYCLES: [(usize, u64); 3] =
    [(32, 2_140_000), (64, 16_600_000), (128, 441_200_000)];

/// Published FlexGrip ratio-vs-eGPU rows of Table 7 (cycles ratio), for
/// regeneration checks: 19.2 / 36.8 / 188.3 at n = 32/64/128.
pub const MMM_CYCLE_RATIO_VS_EGPU: [(usize, f64); 3] = [(32, 19.2), (64, 36.8), (128, 188.3)];

/// FlexGrip MMM cycles for dimension `n`, if published.
pub fn mmm_cycles(n: usize) -> Option<u64> {
    MMM_CYCLES.iter().find(|(d, _)| *d == n).map(|(_, c)| *c)
}

/// Elapsed time in µs at the FlexGrip clock.
pub fn mmm_time_us(n: usize) -> Option<f64> {
    mmm_cycles(n).map(|c| c as f64 / FLEXGRIP_MHZ)
}

/// The paper's §7 aggregate: "FlexGrip underperforms eGPU by a factor of
/// ≈31×, averaged over all benchmarks" (cycle basis).
pub const FLEXGRIP_AVG_CYCLE_RATIO: f64 = 31.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn published_rows_present() {
        assert_eq!(mmm_cycles(32), Some(2_140_000));
        assert_eq!(mmm_cycles(128), Some(441_200_000));
        assert_eq!(mmm_cycles(256), None);
    }

    #[test]
    fn time_at_100mhz() {
        // 2.14M cycles at 100 MHz = 21400 µs (Table 7's "21400").
        assert!((mmm_time_us(32).unwrap() - 21_400.0).abs() < 1.0);
    }

    #[test]
    fn ratio_rows_consistent_with_cycles() {
        // The published ratio rows divided into the published cycles give
        // the eGPU-DP cycle counts the paper reports (±2%).
        let egpu_dp = [(32usize, 111_546f64), (64, 451_066.0), (128, 2_342_356.0)];
        for ((n, ratio), (n2, egpu)) in MMM_CYCLE_RATIO_VS_EGPU.iter().zip(egpu_dp) {
            assert_eq!(*n, n2);
            let implied = mmm_cycles(*n).unwrap() as f64 / egpu;
            assert!(
                (implied - ratio).abs() / ratio < 0.02,
                "n={n}: implied {implied:.1} vs published {ratio}"
            );
        }
    }
}
