//! The five paper benchmarks as scalar Nios programs (paper §7: "we ran
//! all of the benchmarks on Nios IIe ... we replaced the FP32 arithmetic
//! with INT32 for the Nios examples").
//!
//! Memory layouts match the eGPU twins in `crate::kernels` so the same
//! host data drives both machines. The FFT uses Q14 fixed-point twiddles
//! (the INT32 substitution), validated against a float DFT in tests.

use super::nios::{NInstr::*, NiosAsm, NiosProgram};

/// Q-format used for the integer FFT twiddles.
pub const FFT_Q: i32 = 14;

/// Vector reduction: `mem[n] = Σ mem[0..n]`.
pub fn reduction(n: usize) -> NiosProgram {
    let mut a = NiosAsm::new();
    a.emit(Ldi(1, 0)) // acc
        .emit(Ldi(2, 0)) // i
        .emit(Ldi(3, n as i32))
        .label("top")
        .emit(Ld(4, 2, 0))
        .emit(Add(1, 1, 4))
        .emit(AddI(2, 2, 1))
        .branch(|t| Blt(2, 3, t), "top")
        .emit(St(1, 3, 0)) // mem[n] = acc (r3 holds n)
        .emit(Halt);
    a.finish()
}

/// Matrix transpose: `out[j·n + i] = in[i·n + j]`, out at `n²`.
pub fn transpose(n: usize) -> NiosProgram {
    let n = n as i32;
    let mut a = NiosAsm::new();
    a.emit(Ldi(1, 0)) // i
        .emit(Ldi(11, n))
        .label("loop_i")
        .emit(Ldi(2, 0)) // j
        .emit(MulI(4, 1, n)) // in_addr = i*n
        .emit(AddI(5, 1, n * n)) // out_addr = n*n + i
        .label("loop_j")
        .emit(Ld(6, 4, 0))
        .emit(St(6, 5, 0))
        .emit(AddI(4, 4, 1)) // in_addr++
        .emit(AddI(5, 5, n)) // out_addr += n
        .emit(AddI(2, 2, 1))
        .branch(|t| Blt(2, 11, t), "loop_j")
        .emit(AddI(1, 1, 1))
        .branch(|t| Blt(1, 11, t), "loop_i")
        .emit(Halt);
    a.finish()
}

/// Matrix-matrix multiply: `C = A·B` (A at 0, B at n², C at 2n²), INT32.
pub fn mmm(n: usize) -> NiosProgram {
    let n = n as i32;
    let mut a = NiosAsm::new();
    a.emit(Ldi(11, n))
        .emit(Ldi(1, 0)) // i
        .label("loop_i")
        .emit(Ldi(2, 0)) // j
        .label("loop_j")
        .emit(Ldi(3, 0)) // acc
        .emit(Ldi(4, 0)) // k
        .emit(MulI(5, 1, n)) // a_addr = i*n
        .emit(AddI(6, 2, n * n)) // b_addr = n*n + j
        .label("loop_k")
        .emit(Ld(7, 5, 0))
        .emit(Ld(8, 6, 0))
        .emit(Mul(9, 7, 8))
        .emit(Add(3, 3, 9))
        .emit(AddI(5, 5, 1))
        .emit(AddI(6, 6, n))
        .emit(AddI(4, 4, 1))
        .branch(|t| Blt(4, 11, t), "loop_k")
        .emit(MulI(10, 1, n)) // c_addr = 2n² + i*n + j
        .emit(Add(10, 10, 2))
        .emit(AddI(10, 10, 2 * n * n))
        .emit(St(3, 10, 0))
        .emit(AddI(2, 2, 1))
        .branch(|t| Blt(2, 11, t), "loop_j")
        .emit(AddI(1, 1, 1))
        .branch(|t| Blt(1, 11, t), "loop_i")
        .emit(Halt);
    a.finish()
}

/// Bitonic sort of `mem[0..n]` in place, ascending (n a power of two).
pub fn bitonic(n: usize) -> NiosProgram {
    let n = n as i32;
    let mut a = NiosAsm::new();
    // r0 = 0 (kept), r1 = k, r2 = j, r3 = i, r11 = n, r12 = 1
    a.emit(Ldi(0, 0))
        .emit(Ldi(11, n))
        .emit(Ldi(12, 1))
        .emit(Ldi(1, 2)) // k = 2
        .label("loop_k")
        .emit(Shr(2, 1, 12)) // j = k >> 1
        .label("loop_j")
        .emit(Ldi(3, 0)) // i = 0
        .label("loop_i")
        .emit(Xor(4, 3, 2)); // l = i ^ j
    a.branch(|t| Bge(3, 4, t), "next_i"); // only l > i does the exchange
    a.emit(And(5, 3, 1)) // dir = i & k
        .emit(Ld(6, 3, 0)) // a = mem[i]
        .emit(Ld(7, 4, 0)); // b = mem[l]
    a.branch(|t| Bne(5, 0, t), "descending");
    // ascending: swap when a > b  (i.e. skip when b >= a)
    a.branch(|t| Bge(7, 6, t), "next_i");
    a.branch(|t| Jmp(t), "do_swap");
    a.label("descending");
    // descending: swap when a < b  (i.e. skip when a >= b)
    a.branch(|t| Bge(6, 7, t), "next_i");
    a.label("do_swap")
        .emit(St(7, 3, 0))
        .emit(St(6, 4, 0))
        .label("next_i")
        .emit(AddI(3, 3, 1));
    a.branch(|t| Blt(3, 11, t), "loop_i");
    a.emit(Shr(2, 2, 12)); // j >>= 1
    a.branch(|t| Blt(0, 2, t), "loop_j"); // while j > 0
    a.emit(Shl(1, 1, 12)); // k <<= 1
    a.branch(|t| Bge(11, 1, t), "loop_k"); // while k <= n
    a.emit(Halt);
    a.finish()
}

/// Radix-2 DIT FFT over Q14 fixed point (the paper's INT32 substitution).
///
/// Layout: re at 0, im at n, twiddle cos at 2n (n/2 entries), twiddle sin
/// at 2n + n/2. The host preloads twiddles (like the eGPU twin, which has
/// no trig instruction — data load is external, §7).
pub fn fft(n: usize) -> NiosProgram {
    let log2n = n.trailing_zeros() as i32;
    let n = n as i32;
    let mut a = NiosAsm::new();
    // Constants: r11=n, r12=1, r13=Q, r14=log2n, r15=im base, r16=cos
    // base, r17=sin base.
    a.emit(Ldi(0, 0))
        .emit(Ldi(11, n))
        .emit(Ldi(12, 1))
        .emit(Ldi(13, FFT_Q))
        .emit(Ldi(14, log2n))
        .emit(Ldi(15, n))
        .emit(Ldi(16, 2 * n))
        .emit(Ldi(17, 2 * n + n / 2));

    // ---- bit-reverse permutation ----
    a.emit(Ldi(1, 0)) // i
        .label("br_i")
        .emit(Ldi(2, 0)) // j = rev(i)
        .emit(AddI(3, 1, 0)) // t = i
        .emit(Ldi(4, 0)) // b = 0
        .label("br_bits")
        .emit(Shl(2, 2, 12))
        .emit(And(5, 3, 12))
        .emit(Or(2, 2, 5))
        .emit(Shr(3, 3, 12))
        .emit(AddI(4, 4, 1));
    a.branch(|t| Blt(4, 14, t), "br_bits");
    a.branch(|t| Bge(1, 2, t), "br_next"); // swap only when j > i
    a.emit(Ld(5, 1, 0)) // re[i] <-> re[j]
        .emit(Ld(6, 2, 0))
        .emit(St(6, 1, 0))
        .emit(St(5, 2, 0))
        .emit(Add(7, 1, 15)) // im[i] <-> im[j]
        .emit(Add(8, 2, 15))
        .emit(Ld(5, 7, 0))
        .emit(Ld(6, 8, 0))
        .emit(St(6, 7, 0))
        .emit(St(5, 8, 0))
        .label("br_next")
        .emit(AddI(1, 1, 1));
    a.branch(|t| Blt(1, 11, t), "br_i");

    // ---- butterfly stages ----
    // r1 = m (span), r2 = half, r3 = k (group base), r4 = t (in-group)
    a.emit(Ldi(1, 2)); // m = 2
    a.label("stage");
    a.emit(Shr(2, 1, 12)); // half = m >> 1
    a.emit(Ldi(3, 0)); // k = 0
    a.label("group");
    a.emit(Ldi(4, 0)); // t = 0
    a.label("bfly");
    // tw_idx = t * (n / m): n/m = n >> log2(m); compute via division-free
    // running stride is complex scalar-side — use Mul with (n/m) computed
    // per stage: r5 = n/m.
    a.emit(Ldi(18, 0)); // placeholder (kept for register clarity)
    a.emit(AddI(5, 11, 0)); // r5 = n
    a.emit(Ldi(6, 0)); // shift counter
    // n/m: shift n right by log2(m). Compute log2(m) by shifting m.
    a.emit(AddI(7, 1, 0)); // r7 = m
    a.label("div_loop");
    a.emit(Shr(5, 5, 12));
    a.emit(Shr(7, 7, 12));
    a.branch(|t| Blt(12, 7, t), "div_loop"); // while m-shifted > 1
    a.emit(Mul(8, 4, 5)); // tw_idx = t * (n/m)
    a.emit(Add(9, 8, 16)) // &cos
        .emit(Ld(9, 9, 0)) // wr
        .emit(Add(10, 8, 17))
        .emit(Ld(10, 10, 0)) // wi_pos = sin
        .emit(Sub(10, 0, 10)); // wi = -sin (forward transform)
    // u = (re/im)[k + t]; v = (re/im)[k + t + half]
    a.emit(Add(18, 3, 4)) // u index
        .emit(Add(19, 18, 2)) // v index
        .emit(Ld(20, 18, 0)) // ur
        .emit(Add(21, 18, 15))
        .emit(Ld(21, 21, 0)) // ui
        .emit(Ld(22, 19, 0)) // vr
        .emit(Add(23, 19, 15))
        .emit(Ld(23, 23, 0)); // vi
    // p = v * w  (Q14): pr = (vr·wr − vi·wi) >> Q ; pi = (vr·wi + vi·wr) >> Q
    a.emit(Mul(24, 22, 9))
        .emit(Mul(25, 23, 10))
        .emit(Sub(24, 24, 25))
        .emit(Sar(24, 24, 13)) // pr
        .emit(Mul(25, 22, 10))
        .emit(Mul(26, 23, 9))
        .emit(Add(25, 25, 26))
        .emit(Sar(25, 25, 13)); // pi
    // writeback
    a.emit(Add(26, 20, 24)) // ur + pr
        .emit(St(26, 18, 0))
        .emit(Add(26, 21, 25))
        .emit(Add(27, 18, 15))
        .emit(St(26, 27, 0))
        .emit(Sub(26, 20, 24))
        .emit(St(26, 19, 0))
        .emit(Sub(26, 21, 25))
        .emit(Add(27, 19, 15))
        .emit(St(26, 27, 0));
    a.emit(AddI(4, 4, 1));
    a.branch(|t| Blt(4, 2, t), "bfly"); // t < half
    a.emit(Add(3, 3, 1)); // k += m  (r1 = m)
    a.branch(|t| Blt(3, 11, t), "group"); // k < n
    a.emit(Shl(1, 1, 12)); // m <<= 1
    a.branch(|t| Bge(11, 1, t), "stage"); // m <= n
    a.emit(Halt);
    a.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::nios::Nios;

    #[test]
    fn reduction_correct_and_cpi() {
        for n in [32usize, 64, 128] {
            let mut m = Nios::new(n + 1);
            for i in 0..n {
                m.mem[i] = i as i32 + 1;
            }
            let s = m.run(&reduction(n), 10_000_000).unwrap();
            assert_eq!(m.mem[n], (n * (n + 1) / 2) as i32);
            // Paper: most benchmarks retire an instruction every ~1.7
            // cycles on Nios.
            assert!(
                (1.2..=2.4).contains(&s.cpi()),
                "n={n}: CPI {:.2}",
                s.cpi()
            );
        }
    }

    #[test]
    fn transpose_correct() {
        let n = 16;
        let mut m = Nios::new(2 * n * n);
        for i in 0..n * n {
            m.mem[i] = i as i32;
        }
        m.run(&transpose(n), 10_000_000).unwrap();
        for i in 0..n {
            for j in 0..n {
                assert_eq!(m.mem[n * n + j * n + i], (i * n + j) as i32);
            }
        }
    }

    #[test]
    fn mmm_correct_and_mul_heavy_cpi() {
        let n = 8;
        let mut m = Nios::new(3 * n * n);
        for i in 0..n * n {
            m.mem[i] = (i % 7) as i32 - 3;
            m.mem[n * n + i] = (i % 5) as i32 - 2;
        }
        let a: Vec<i32> = m.mem[0..n * n].to_vec();
        let b: Vec<i32> = m.mem[n * n..2 * n * n].to_vec();
        let s = m.run(&mmm(n), 100_000_000).unwrap();
        for i in 0..n {
            for j in 0..n {
                let want: i32 = (0..n).map(|k| a[i * n + k] * b[k * n + j]).sum();
                assert_eq!(m.mem[2 * n * n + i * n + j], want, "C[{i}][{j}]");
            }
        }
        // §7: the MMM retires ~3 cycles/instruction (32×32 multiplies).
        assert!((2.0..=3.6).contains(&s.cpi()), "CPI {:.2}", s.cpi());
    }

    #[test]
    fn bitonic_sorts() {
        for n in [32usize, 128] {
            let mut m = Nios::new(n);
            let mut lcg = 0x2545F4914F6CDD1Du64;
            for i in 0..n {
                lcg = lcg.wrapping_mul(6364136223846793005).wrapping_add(1);
                m.mem[i] = (lcg >> 33) as i32 - (1 << 30);
            }
            m.run(&bitonic(n), 100_000_000).unwrap();
            for i in 1..n {
                assert!(m.mem[i - 1] <= m.mem[i], "n={n}: unsorted at {i}");
            }
        }
    }

    #[test]
    fn fft_matches_float_dft() {
        let n = 32usize;
        let mut m = Nios::new(3 * n);
        // Input: a couple of tones, Q14-scaled.
        let scale = (1 << FFT_Q) as f64;
        let mut re = vec![0f64; n];
        let im = vec![0f64; n];
        for (i, r) in re.iter_mut().enumerate() {
            *r = (2.0 * std::f64::consts::PI * 3.0 * i as f64 / n as f64).cos()
                + 0.5 * (2.0 * std::f64::consts::PI * 7.0 * i as f64 / n as f64).sin();
        }
        for i in 0..n {
            m.mem[i] = (re[i] * scale * 0.25) as i32; // headroom
            m.mem[n + i] = (im[i] * scale * 0.25) as i32;
        }
        for t in 0..n / 2 {
            let w = 2.0 * std::f64::consts::PI * t as f64 / n as f64;
            m.mem[2 * n + t] = (w.cos() * scale) as i32;
            m.mem[2 * n + n / 2 + t] = (w.sin() * scale) as i32;
        }
        m.run(&fft(n), 100_000_000).unwrap();
        // Float DFT of the same (quantized) input.
        let qre: Vec<f64> = (0..n).map(|i| (re[i] * scale * 0.25).trunc() / scale).collect();
        let qim: Vec<f64> = (0..n).map(|i| (im[i] * scale * 0.25).trunc() / scale).collect();
        for k in 0..n {
            let (mut xr, mut xi) = (0f64, 0f64);
            for t in 0..n {
                let w = -2.0 * std::f64::consts::PI * (k * t) as f64 / n as f64;
                xr += qre[t] * w.cos() - qim[t] * w.sin();
                xi += qre[t] * w.sin() + qim[t] * w.cos();
            }
            let got_r = m.mem[k] as f64 / scale;
            let got_i = m.mem[n + k] as f64 / scale;
            assert!(
                (got_r - xr).abs() < 0.05 && (got_i - xi).abs() < 0.05,
                "bin {k}: got ({got_r:.3},{got_i:.3}) want ({xr:.3},{xi:.3})"
            );
        }
    }

    #[test]
    fn paper_scale_cycle_counts() {
        // Shape check against Table 7/8 Nios columns (same OOM, not
        // exact): reduction-32 ≈ 459 cycles, transpose-32 ≈ 21.8k,
        // MMM-32 ≈ 1.45M, bitonic-32 ≈ 8.5k, FFT-32 ≈ 9.2k.
        let mut m = Nios::new(64);
        let s = m.run(&reduction(32), 1_000_000).unwrap();
        assert!((200..=1200).contains(&s.cycles), "reduction {}", s.cycles);

        let mut m = Nios::new(2 * 32 * 32);
        let s = m.run(&transpose(32), 10_000_000).unwrap();
        assert!((8_000..=40_000).contains(&s.cycles), "transpose {}", s.cycles);

        let mut m = Nios::new(3 * 32 * 32);
        let s = m.run(&mmm(32), 100_000_000).unwrap();
        assert!(
            (400_000..=2_500_000).contains(&s.cycles),
            "mmm {}",
            s.cycles
        );

        let mut m = Nios::new(32);
        let s = m.run(&bitonic(32), 10_000_000).unwrap();
        assert!((3_000..=20_000).contains(&s.cycles), "bitonic {}", s.cycles);

        let mut m = Nios::new(3 * 32);
        let s = m.run(&fft(32), 10_000_000).unwrap();
        assert!((4_000..=30_000).contains(&s.cycles), "fft {}", s.cycles);
    }
}
