//! Resource, frequency and cost models (paper §5, §6; Tables 1, 4, 5, 6).
//!
//! The paper's evaluation platform is Quartus place-and-route on an Agilex
//! AGIB027R29A1E1V; this module is the substitution (DESIGN.md §3): an
//! analytical model built from the paper's own composition rules —
//! M20K counts from §5.1/§5.5 formulas, integer-ALU costs from Table 6,
//! per-component ALM/FF budgets from §5.5 — with interaction constants
//! calibrated by least squares against the ten Table 4/5 rows (see
//! `resources.rs` for the calibration). `rust/tests/paper_tables.rs`
//! asserts every row is regenerated within tolerance.

pub mod alu_model;
pub mod cost;
pub mod frequency;
pub mod memory_model;
pub mod resources;

pub use cost::{normalized_cost, ppa_metric};
pub use frequency::FrequencyReport;
pub use resources::ResourceReport;
