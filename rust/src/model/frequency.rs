//! Fmax model (paper §6 "Repeatable High Performance").
//!
//! The paper's claim: the eGPU *always* closes timing at the speed of the
//! slowest embedded component — 771 MHz (the 4-stage FP32 DSP datapath)
//! for DP memory, 600 MHz (the emulated quad-port M20K) for QP — because
//! the soft-logic paths are architected to exceed those limits. Tables 4/5
//! report both the soft-path Fmax and the embedded limit ("Freq" column,
//! e.g. "1018/771").
//!
//! The embedded limits are physical constants; the soft-path Fmax is
//! modeled as a wireload function of design size and predicate fan-out,
//! calibrated against the ten reported rows (±6%).

use crate::sim::config::{EgpuConfig, MemoryMode};

use super::resources::ResourceReport;

/// Agilex clock-network limit (§6).
pub const CLOCK_NETWORK_MHZ: f64 = 1000.0;
/// FP32 multiply-add DSP with a 4-stage pipeline (§6, [11]).
pub const DSP_FP32_MHZ: f64 = 771.0;
/// M20K in simple dual-port mode.
pub const M20K_DP_MHZ: f64 = 1000.0;
/// M20K in emulated quad-port mode.
pub const M20K_QP_MHZ: f64 = 600.0;

// Calibrated soft-path wireload model: a − b·(ALM/1000) − c·levels − d·QP.
const SOFT_A: f64 = 1093.3;
const SOFT_B: f64 = 25.1;
const SOFT_C: f64 = -2.5; // levels mildly *help* after size is accounted
const SOFT_D: f64 = 125.7;

/// The Table 4/5 "Freq" column: soft-path Fmax / embedded limit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FrequencyReport {
    /// Slowest path outside the embedded blocks (modeled wireload).
    pub soft_mhz: f64,
    /// The embedded limit that actually clocks the core.
    pub embedded_mhz: f64,
    /// Achieved core clock = min(everything).
    pub core_mhz: f64,
    /// True when the soft logic is not the limiter (the paper's repeatable
    /// timing-closure claim).
    pub closes_at_embedded_limit: bool,
}

impl FrequencyReport {
    pub fn for_config(cfg: &EgpuConfig) -> FrequencyReport {
        let r = ResourceReport::for_config(cfg);
        Self::for_resources(cfg, &r)
    }

    pub fn for_resources(cfg: &EgpuConfig, r: &ResourceReport) -> FrequencyReport {
        let embedded = match cfg.memory {
            MemoryMode::Dp => DSP_FP32_MHZ.min(M20K_DP_MHZ),
            MemoryMode::Qp => DSP_FP32_MHZ.min(M20K_QP_MHZ),
        };
        let qp = matches!(cfg.memory, MemoryMode::Qp) as u8 as f64;
        let mut soft = SOFT_A
            - SOFT_B * (r.alms as f64 / 1000.0)
            - SOFT_C * cfg.predicate_levels as f64
            - SOFT_D * qp;
        // The wireload fit already reflects the ALU pipeline's
        // contribution (§5.2); only the physical clock network clamps.
        soft = soft.min(CLOCK_NETWORK_MHZ);
        let core = soft.min(embedded);
        FrequencyReport {
            soft_mhz: soft,
            embedded_mhz: embedded,
            core_mhz: core,
            closes_at_embedded_limit: soft >= embedded,
        }
    }

    /// Achieved core clock in integer kHz — the exact-arithmetic form
    /// the fleet dispatcher uses to convert per-core cycle counts onto
    /// the shared bus timeline (771 MHz → 771_000). Integer kHz keeps
    /// heterogeneous wall-clock comparisons deterministic (no float
    /// accumulation in the modeled timeline).
    pub fn core_khz(&self) -> u64 {
        (self.core_mhz * 1000.0).round() as u64
    }
}

/// Modeled core clock of a configuration in kHz: the embedded limit
/// when the soft paths clear it (the §6 repeatable-closure claim, true
/// of every Table 4/5 instance), otherwise the wireload-modeled soft
/// Fmax. This is what wall-clock-aware placement runs on.
pub fn modeled_core_khz(cfg: &EgpuConfig) -> u64 {
    FrequencyReport::for_config(cfg).core_khz()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::EgpuConfig;

    /// Paper Table 4/5 "Freq" column (soft, embedded).
    const TABLE4_FREQ: [(f64, f64); 6] = [
        (1018.0, 771.0),
        (898.0, 771.0),
        (883.0, 771.0),
        (902.0, 771.0),
        (860.0, 771.0),
        (841.0, 771.0),
    ];
    const TABLE5_FREQ: [(f64, f64); 4] =
        [(840.0, 600.0), (763.0, 600.0), (763.0, 600.0), (714.0, 600.0)];

    #[test]
    fn every_instance_closes_at_the_embedded_limit() {
        // The headline §6 claim, for all ten paper rows.
        for cfg in EgpuConfig::table4_presets()
            .iter()
            .chain(EgpuConfig::table5_presets().iter())
        {
            let f = FrequencyReport::for_config(cfg);
            assert!(
                f.closes_at_embedded_limit,
                "{}: soft {:.0} < embedded {:.0}",
                cfg.name, f.soft_mhz, f.embedded_mhz
            );
            let want = match cfg.memory {
                MemoryMode::Dp => 771.0,
                MemoryMode::Qp => 600.0,
            };
            assert_eq!(f.core_mhz, want, "{}", cfg.name);
        }
    }

    #[test]
    fn soft_path_within_8pct_of_paper() {
        for (cfg, (soft, emb)) in EgpuConfig::table4_presets()
            .iter()
            .zip(TABLE4_FREQ)
            .chain(EgpuConfig::table5_presets().iter().zip(TABLE5_FREQ))
        {
            let f = FrequencyReport::for_config(cfg);
            let err = (f.soft_mhz - soft).abs() / soft * 100.0;
            assert!(
                err < 8.0,
                "{}: soft model {:.0} vs paper {soft} ({err:.1}%)",
                cfg.name,
                f.soft_mhz
            );
            assert_eq!(f.embedded_mhz, emb, "{}", cfg.name);
        }
    }

    #[test]
    fn khz_conversion_is_exact_for_the_embedded_limits() {
        let dp = EgpuConfig::table4_presets().remove(0);
        let qp = EgpuConfig::table5_presets().remove(0);
        assert_eq!(modeled_core_khz(&dp), 771_000);
        assert_eq!(modeled_core_khz(&qp), 600_000);
    }

    #[test]
    fn qp_caps_at_600() {
        let f = FrequencyReport::for_config(&EgpuConfig::table5_presets()[0]);
        assert_eq!(f.embedded_mhz, 600.0);
        assert!(f.soft_mhz < 900.0); // QP wire penalty visible
    }

    #[test]
    fn nothing_exceeds_the_clock_network() {
        for cfg in EgpuConfig::table4_presets() {
            let f = FrequencyReport::for_config(&cfg);
            assert!(f.soft_mhz <= CLOCK_NETWORK_MHZ);
        }
    }
}
