//! ALM / flip-flop resource model (Tables 4 and 5).
//!
//! Component structure follows §5.5: each SP = overhead mux/control +
//! integer ALU (Table 6) (+ predicate block), plus the instruction
//! fetch/decode/control section and the shared-memory access network.
//! The interaction constants below were calibrated once by least squares
//! against the ten Table 4/5 rows (script recorded in EXPERIMENTS.md);
//! `rust/tests/paper_tables.rs` holds every row to ±8%.

use crate::sim::config::EgpuConfig;

use super::alu_model::{alu_cost, AluCost};
use super::memory_model::{dsp_blocks, total_m20ks};

// --- calibrated ALM model constants -----------------------------------
/// Per-SP mux/control overhead (§5.5 estimates ≈150; the fit, which also
/// absorbs per-SP pipelining registers, lands slightly higher).
const ALM_SP_OVERHEAD: f64 = 170.0;
/// Predicate cost per initialized thread (§5.3 "may only be 5 ALMs per
/// thread" including control; the per-thread stack bit itself fits ~2).
const ALM_PRED_PER_THREAD: f64 = 1.92;
/// Predicate stack-depth cost per SP per nesting level.
const ALM_PRED_PER_LEVEL_SP: f64 = 9.58;
/// Instruction fetch/decode/control + shared-memory network base.
const ALM_CONTROL_BASE: f64 = 10.6;
/// Shared-memory mux/pipeline per KB (slightly negative after the other
/// terms absorb the common-mode cost — a pure interaction correction).
const ALM_PER_SHARED_KB: f64 = -1.9;
/// Register-space interaction corrections (wider register addressing is
/// already partially counted in the per-thread predicate term).
const ALM_REGS32_CORR: f64 = -359.0;
const ALM_REGS64_CORR: f64 = -1136.0;
/// QP write-network adder (the two-write-port emulation logic).
const ALM_QP_CORR: f64 = 1371.0;

// --- calibrated flip-flop model constants ------------------------------
const FF_SP_OVERHEAD: f64 = 688.6;
const FF_PRED_PER_THREAD: f64 = 7.97;
const FF_CONTROL_BASE: f64 = 43.0;
const FF_PER_SHARED_KB: f64 = -2.73;
const FF_QP_CORR: f64 = 530.8;
const FF_REGS64_CORR: f64 = -461.2;

/// Modeled resources of one eGPU instance (a Table 4/5 row).
#[derive(Debug, Clone, PartialEq)]
pub struct ResourceReport {
    pub name: String,
    pub alms: u32,
    pub registers: u32,
    pub dsps: u32,
    pub m20ks: u32,
    /// Per-SP share: (ALMs, FFs) — the Table 4/5 "SP (ALM/Reg.)" column.
    pub sp_alms: u32,
    pub sp_regs: u32,
    pub alu: AluCost,
}

/// Modeled predicate-block ALMs per SP (0 when predicates are omitted).
/// The placer uses this to split each SP's share between the contiguous
/// datapath block and the remotely-placed predicate block (Figure 4).
pub fn pred_alms_per_sp(cfg: &EgpuConfig) -> u32 {
    if cfg.predicate_levels == 0 {
        return 0;
    }
    let total = cfg.threads as f64 * ALM_PRED_PER_THREAD
        + 16.0 * cfg.predicate_levels as f64 * ALM_PRED_PER_LEVEL_SP;
    (total / 16.0).round() as u32
}

impl ResourceReport {
    pub fn for_config(cfg: &EgpuConfig) -> ResourceReport {
        let alu = alu_cost(cfg);
        let pred_on = cfg.predicate_levels > 0;
        let qp = matches!(cfg.memory, crate::sim::config::MemoryMode::Qp);

        let mut alms = 16.0 * (ALM_SP_OVERHEAD + alu.alms as f64);
        if pred_on {
            alms += cfg.threads as f64 * ALM_PRED_PER_THREAD
                + 16.0 * cfg.predicate_levels as f64 * ALM_PRED_PER_LEVEL_SP;
        }
        alms += ALM_CONTROL_BASE + cfg.shared_kb as f64 * ALM_PER_SHARED_KB;
        if cfg.regs_per_thread >= 32 {
            alms += ALM_REGS32_CORR;
        }
        if cfg.regs_per_thread == 64 {
            alms += ALM_REGS64_CORR;
        }
        if qp {
            alms += ALM_QP_CORR;
        }

        let mut ffs = 16.0 * (FF_SP_OVERHEAD + alu.regs as f64);
        if pred_on {
            ffs += cfg.threads as f64 * FF_PRED_PER_THREAD;
        }
        ffs += FF_CONTROL_BASE + cfg.shared_kb as f64 * FF_PER_SHARED_KB;
        if qp {
            ffs += FF_QP_CORR;
        }
        if cfg.regs_per_thread == 64 {
            ffs += FF_REGS64_CORR;
        }

        let alms = alms.round().max(0.0) as u32;
        let ffs = ffs.round().max(0.0) as u32;
        ResourceReport {
            name: cfg.name.clone(),
            alms,
            registers: ffs,
            dsps: dsp_blocks(cfg) as u32,
            m20ks: total_m20ks(cfg) as u32,
            sp_alms: alms / 16,
            sp_regs: ffs / 16,
            alu,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::EgpuConfig;

    /// Paper Table 4 (ALM, FF) columns, row order.
    pub const TABLE4_ALM_FF: [(u32, u32); 6] = [
        (4243, 13635),
        (7518, 18992),
        (7579, 19155),
        (9754, 25425),
        (10127, 26040),
        (10697, 26618),
    ];

    /// Paper Table 5 (ALM, FF) columns, row order.
    pub const TABLE5_ALM_FF: [(u32, u32); 4] =
        [(5468, 14487), (7057, 16722), (11314, 25050), (10174, 23094)];

    fn pct(a: u32, b: u32) -> f64 {
        (a as f64 - b as f64).abs() / b as f64 * 100.0
    }

    #[test]
    fn table4_alm_within_8pct() {
        for (cfg, (alm, ff)) in EgpuConfig::table4_presets().iter().zip(TABLE4_ALM_FF) {
            let r = ResourceReport::for_config(cfg);
            assert!(
                pct(r.alms, alm) < 8.0,
                "{}: model {} vs paper {alm}",
                cfg.name,
                r.alms
            );
            assert!(
                pct(r.registers, ff) < 8.0,
                "{}: model {} vs paper {ff}",
                cfg.name,
                r.registers
            );
        }
    }

    #[test]
    fn table5_alm_within_8pct() {
        for (cfg, (alm, ff)) in EgpuConfig::table5_presets().iter().zip(TABLE5_ALM_FF) {
            let r = ResourceReport::for_config(cfg);
            assert!(
                pct(r.alms, alm) < 8.0,
                "{}: model {} vs paper {alm}",
                cfg.name,
                r.alms
            );
            assert!(
                pct(r.registers, ff) < 8.0,
                "{}: model {} vs paper {ff}",
                cfg.name,
                r.registers
            );
        }
    }

    #[test]
    fn sp_size_range_matches_paper() {
        // §5.5: "A single SP will therefore be as small as 250 ALMs, and
        // can be as large as 650 ALMs" — the modeled per-SP shares of the
        // Table 4/5 rows must stay in that envelope (±15%).
        for cfg in EgpuConfig::table4_presets()
            .iter()
            .chain(EgpuConfig::table5_presets().iter())
        {
            let r = ResourceReport::for_config(cfg);
            assert!(
                (210..=750).contains(&r.sp_alms),
                "{}: SP share {} out of envelope",
                cfg.name,
                r.sp_alms
            );
        }
    }

    #[test]
    fn predicates_add_about_half_the_soft_logic() {
        // §5.3 / Table 5: large-QP with 16 predicate levels vs the same
        // machine without predicates → ≈ +50% ALMs.
        let cfgs = EgpuConfig::table5_presets();
        let without = ResourceReport::for_config(&cfgs[1]);
        let with = ResourceReport::for_config(&cfgs[2]);
        let ratio = with.alms as f64 / without.alms as f64;
        assert!(
            (1.3..=1.8).contains(&ratio),
            "predicate ratio {ratio:.2} outside [1.3, 1.8]"
        );
    }

    #[test]
    fn small_core_is_4k_large_is_10k() {
        // §1: "a logic range – depending on the configuration – of 4k to
        // 10k ALMs".
        let rows: Vec<u32> = EgpuConfig::table4_presets()
            .iter()
            .map(|c| ResourceReport::for_config(c).alms)
            .collect();
        assert!(rows[0] < 5000, "small {}", rows[0]);
        assert!(rows[5] > 9500, "large {}", rows[5]);
    }
}
