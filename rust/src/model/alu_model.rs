//! Integer-ALU resource model (paper Table 6, §5.2).
//!
//! The soft-logic integer ALU is the largest SP component: "up to half of
//! the soft logic and registers in an eGPU is required for the integer
//! ALU". Table 6 gives measured ALM/FF and per-function breakdowns; this
//! module reproduces that table and resolves a configuration to its ALU
//! cost.

use crate::sim::config::{EgpuConfig, IntAluClass, MemoryMode};

/// One Table 6 row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AluCost {
    pub precision: u8,
    pub class: IntAluClass,
    pub alms: u32,
    pub regs: u32,
    /// Per-function ALM breakdown (None where the paper reports "-").
    pub add_sub: Option<u32>,
    pub logic: Option<u32>,
    pub shl: Option<u32>,
    pub shr: Option<u32>,
    pub pop: Option<u32>,
    /// Pipeline stages (5 for the 800 MHz ALUs, 4 for the QP variant).
    pub stages: u8,
}

/// The five Table 6 rows, in paper order.
pub const TABLE6: [AluCost; 5] = [
    AluCost {
        precision: 16,
        class: IntAluClass::Min,
        alms: 90,
        regs: 136,
        add_sub: Some(3),
        logic: Some(9),
        shl: None,
        shr: None,
        pop: None,
        stages: 5,
    },
    AluCost {
        precision: 16,
        class: IntAluClass::Small,
        alms: 134,
        regs: 207,
        add_sub: Some(9),
        logic: Some(10),
        shl: Some(20),
        shr: Some(23),
        pop: None,
        stages: 5,
    },
    AluCost {
        precision: 16,
        class: IntAluClass::Full,
        alms: 199,
        regs: 269,
        add_sub: Some(9),
        logic: Some(18),
        shl: Some(20),
        shr: Some(23),
        pop: Some(11),
        stages: 5,
    },
    AluCost {
        precision: 32,
        class: IntAluClass::Min,
        alms: 208,
        regs: 406,
        add_sub: Some(5),
        logic: Some(27),
        shl: Some(28),
        shr: Some(28),
        pop: None,
        stages: 5,
    },
    AluCost {
        precision: 32,
        class: IntAluClass::Full,
        alms: 394,
        regs: 704,
        add_sub: Some(27),
        logic: Some(36),
        shl: Some(50),
        shr: Some(53),
        pop: Some(27),
        stages: 5,
    },
];

/// The 4-stage 32-bit ALU used by QP configurations (§5.2: "about the
/// size of the 16-bit full function ALU", ~700 MHz — acceptable because
/// the QP memory caps the core at 600 MHz anyway).
pub const QP_32_FULL: AluCost = AluCost {
    precision: 32,
    class: IntAluClass::Full,
    alms: 200,
    regs: 280,
    add_sub: Some(14),
    logic: Some(36),
    shl: Some(50),
    shr: Some(53),
    pop: Some(27),
    stages: 4,
};

/// Resolve a configuration's integer-ALU cost.
///
/// QP configurations use the 4-stage variant; DP configurations take the
/// Table 6 row matching (precision, class), falling back to the Full row
/// of their precision for the Small-32 combination the paper doesn't
/// tabulate.
pub fn alu_cost(cfg: &EgpuConfig) -> AluCost {
    if cfg.memory == MemoryMode::Qp && cfg.alu_precision == 32 {
        return QP_32_FULL;
    }
    let want = |p: u8, c: IntAluClass| {
        TABLE6
            .iter()
            .copied()
            .find(|r| r.precision == p && r.class == c)
    };
    want(cfg.alu_precision, cfg.int_alu)
        .or_else(|| want(cfg.alu_precision, IntAluClass::Full))
        .expect("every precision has a Full row")
}

/// ALU Fmax in MHz (§5.2: 5-stage always exceeds 800 MHz; the 4-stage
/// variant "returns a lower performance (typically 700 MHz)").
pub fn alu_fmax(cost: &AluCost) -> f64 {
    if cost.stages >= 5 {
        810.0
    } else {
        700.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table6_rows_match_paper() {
        assert_eq!(TABLE6[0].alms, 90);
        assert_eq!(TABLE6[0].regs, 136);
        assert_eq!(TABLE6[2].alms, 199);
        assert_eq!(TABLE6[4].alms, 394);
        assert_eq!(TABLE6[4].regs, 704);
    }

    #[test]
    fn doubling_structure() {
        // §5.2: full 16-bit ≈ 2× min 16-bit; full 32-bit ≈ 2× full 16-bit
        // in ALMs, ~3× min-16 registers for the 32-bit pipelines.
        let min16 = TABLE6[0].alms as f64;
        let full16 = TABLE6[2].alms as f64;
        let full32 = TABLE6[4].alms as f64;
        assert!((full16 / min16 - 2.2).abs() < 0.3);
        assert!((full32 / full16 - 2.0).abs() < 0.25);
        assert!((TABLE6[4].regs as f64 / TABLE6[2].regs as f64 - 2.6).abs() < 0.3);
    }

    #[test]
    fn config_resolution() {
        let mut cfg = EgpuConfig::default(); // 32-bit Full, DP
        assert_eq!(alu_cost(&cfg).alms, 394);
        cfg.alu_precision = 16;
        cfg.int_alu = IntAluClass::Min;
        assert_eq!(alu_cost(&cfg).alms, 90);
        cfg.memory = MemoryMode::Qp;
        cfg.alu_precision = 32;
        assert_eq!(alu_cost(&cfg).alms, 200);
        assert_eq!(alu_cost(&cfg).stages, 4);
    }

    #[test]
    fn fmax_by_stages() {
        assert!(alu_fmax(&TABLE6[4]) > 800.0);
        assert!((alu_fmax(&QP_32_FULL) - 700.0).abs() < 1.0);
    }

    #[test]
    fn untabulated_small32_falls_back_to_full() {
        let mut cfg = EgpuConfig::default();
        cfg.int_alu = IntAluClass::Small;
        assert_eq!(alu_cost(&cfg).alms, 394);
    }
}
