//! M20K and DSP block counts (paper §5.1, §5.5).
//!
//! These are the paper's own closed-form rules:
//!
//! - DP thread registers: `threads × regs / 256` M20Ks (two replicated
//!   dual-port blocks per SP give the 2R + 1W ports).
//! - DP shared memory: `2 × size_KB` M20Ks (4 read-port replicas of
//!   512×32 blocks, 1 write port).
//! - QP halves both, *except* small register spaces
//!   (`threads × regs / 16 ≤ 2047`) where the 2048×8 QP geometry forces
//!   the DP count.
//! - Instruction store: bit-packed M20Ks (see `Program::instruction_m20ks`
//!   for the program-sized variant; configurations budget a 1k-word
//!   multi-tenant store, §5.4).
//! - DSP blocks: 16 FP32 DSPs (one per SP) + 8 integer-multiply DSPs
//!   (shared one per two SPs), replicated to 16 when the register column
//!   footprint exceeds one M20K column (§5.6) — DP with 64 regs/thread,
//!   QP at ≥1024 threads. The optional dot-product core adds a 16-input
//!   FP32 reduction tree (8 + 4 + 2 + 1 two-input adders ≈ 15 DSPs,
//!   packed as 8 dual-use blocks).

use crate::sim::config::{EgpuConfig, MemoryMode};

/// M20Ks for the thread register files.
pub fn regfile_m20ks(cfg: &EgpuConfig) -> usize {
    let dp = cfg.threads * cfg.regs_per_thread / 256;
    match cfg.memory {
        MemoryMode::Dp => dp,
        MemoryMode::Qp => {
            if cfg.threads * cfg.regs_per_thread / 16 > 2047 {
                dp / 2
            } else {
                dp // minimum-size rule: same as DP
            }
        }
    }
}

/// M20Ks for the shared memory.
pub fn shared_m20ks(cfg: &EgpuConfig) -> usize {
    let dp = 2 * cfg.shared_kb;
    match cfg.memory {
        MemoryMode::Dp => dp,
        MemoryMode::Qp => dp / 2,
    }
}

/// M20Ks budgeted for the (multi-tenant, §5.4) instruction store: a
/// 1k-word program space at this configuration's IW width.
pub fn instruction_m20ks(cfg: &EgpuConfig) -> usize {
    let bits = cfg.word_layout().word_bits() as usize;
    // ⌈1024 · bits / 20480⌉, i.e. 2 for 40-bit, 3 for 43/46-bit words.
    (1024 * bits).div_ceil(20480)
}

/// Total M20K count (Table 4/5 "M20K" column).
pub fn total_m20ks(cfg: &EgpuConfig) -> usize {
    regfile_m20ks(cfg) + shared_m20ks(cfg) + instruction_m20ks(cfg)
}

/// DSP blocks (Table 4/5 "DSP" column).
pub fn dsp_blocks(cfg: &EgpuConfig) -> usize {
    let fp = 16; // one FP32 multiply-add DSP per SP
    let int_mul = if wide_register_columns(cfg) { 16 } else { 8 };
    let dot = if cfg.dot_core { 8 } else { 0 };
    fp + int_mul + dot
}

/// Does the register space spill past one M20K column per SP (§5.6)?
fn wide_register_columns(cfg: &EgpuConfig) -> bool {
    match cfg.memory {
        MemoryMode::Dp => cfg.regs_per_thread == 64,
        MemoryMode::Qp => cfg.threads >= 1024,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::EgpuConfig;

    #[test]
    fn paper_worked_examples() {
        // §5.1: 512 threads × 16 regs → "32 M20Ks for thread registers".
        let mut cfg = EgpuConfig::default();
        cfg.regs_per_thread = 16;
        assert_eq!(regfile_m20ks(&cfg), 32);
        // "a 64KB shared memory needs 128 M20Ks, and a 128KB ... 256".
        cfg.shared_kb = 64;
        assert_eq!(shared_m20ks(&cfg), 128);
        cfg.shared_kb = 128;
        assert_eq!(shared_m20ks(&cfg), 256);
        // "2KB ... would require four M20Ks", "8KB ... 16 M20Ks".
        cfg.shared_kb = 2;
        assert_eq!(shared_m20ks(&cfg), 4);
        cfg.shared_kb = 8;
        assert_eq!(shared_m20ks(&cfg), 16);
    }

    #[test]
    fn table4_m20k_column_exact() {
        let expect = [50usize, 98, 131, 131, 195, 259];
        for (cfg, want) in EgpuConfig::table4_presets().iter().zip(expect) {
            assert_eq!(total_m20ks(cfg), want, "{}", cfg.name);
        }
    }

    #[test]
    fn table5_m20k_column_within_one() {
        let expect = [98usize, 131, 131, 195];
        for (cfg, want) in EgpuConfig::table5_presets().iter().zip(expect) {
            let got = total_m20ks(cfg);
            assert!(
                (got as i64 - want as i64).abs() <= 1,
                "{}: got {got}, want {want}",
                cfg.name
            );
        }
    }

    #[test]
    fn table45_dsp_column_exact() {
        let expect4 = [24usize, 24, 24, 24, 32, 32];
        for (cfg, want) in EgpuConfig::table4_presets().iter().zip(expect4) {
            assert_eq!(dsp_blocks(cfg), want, "{}", cfg.name);
        }
        let expect5 = [24usize, 32, 32, 32];
        for (cfg, want) in EgpuConfig::table5_presets().iter().zip(expect5) {
            assert_eq!(dsp_blocks(cfg), want, "{}", cfg.name);
        }
    }

    #[test]
    fn qp_halves_memory_except_minimum() {
        // Table 5 small: 512 × 64 regs = 2048 × 16 > 2047 → halved.
        let c = &EgpuConfig::table5_presets()[0];
        assert_eq!(regfile_m20ks(c), 64); // DP would be 128
        // A QP config below the minimum keeps the DP count.
        let mut small = EgpuConfig::default();
        small.memory = MemoryMode::Qp;
        small.regs_per_thread = 16; // 512×16/16 = 512 ≤ 2047
        assert_eq!(regfile_m20ks(&small), 512 * 16 / 256);
    }

    #[test]
    fn dot_core_adds_dsps() {
        let base = EgpuConfig::benchmark(MemoryMode::Dp, false);
        let dot = EgpuConfig::benchmark(MemoryMode::Dp, true);
        assert_eq!(dsp_blocks(&dot) - dsp_blocks(&base), 8);
    }

    #[test]
    fn instruction_store_by_word_width() {
        let mut cfg = EgpuConfig::default();
        cfg.regs_per_thread = 16; // 40-bit IW
        assert_eq!(instruction_m20ks(&cfg), 2);
        cfg.regs_per_thread = 32; // 43-bit
        assert_eq!(instruction_m20ks(&cfg), 3);
        cfg.regs_per_thread = 64; // 46-bit
        assert_eq!(instruction_m20ks(&cfg), 3);
    }
}
