//! Normalized cost and the Table 1 PPA comparison (paper §2, §7).
//!
//! §7: "we normalize the performance (time) by the resource cost, which we
//! calculated on the basis of ALMs and DSP Blocks. We estimate that the
//! effective cost of a DSP block is 100 ALMs" (≈650-ALM soft FP32
//! multiply-add, +50% DSP overhead, ÷10 hard/soft scaling).

use crate::sim::config::EgpuConfig;

use super::resources::ResourceReport;

/// Effective ALM cost of one DSP block (§7 derivation).
pub const DSP_ALM_EQUIVALENT: f64 = 100.0;

/// Normalized resource cost in ALM-equivalents.
pub fn normalized_cost(alms: u32, dsps: u32) -> f64 {
    alms as f64 + dsps as f64 * DSP_ALM_EQUIVALENT
}

/// The paper's *reported* normalized costs for the §7 benchmark variants
/// ("equivalent cost of 7400, 8400, and 9000 ALMs for the eGPU-DP,
/// eGPU-QP, and eGPU-Dot variants") and Nios (1400, 347 MHz). The
/// Table 7/8 "Normalized" rows are computed with these, exactly as the
/// paper does; `config_cost` is the model-derived alternative.
pub const BENCH_COST_DP: f64 = 7400.0;
pub const BENCH_COST_QP: f64 = 8400.0;
pub const BENCH_COST_DOT: f64 = 9000.0;
pub const BENCH_COST_NIOS: f64 = 1400.0;

/// Normalized cost of a configuration.
pub fn config_cost(cfg: &EgpuConfig) -> f64 {
    let r = ResourceReport::for_config(cfg);
    normalized_cost(r.alms, r.dsps)
}

/// [`DSP_ALM_EQUIVALENT`] as an integer, for fixed-point comparisons.
pub const DSP_ALM_EQUIVALENT_U64: u64 = 100;

/// Fixed-point normalized cost in whole ALM-equivalents. Both inputs
/// are integer resource counts and the DSP weight is a whole number,
/// so this is exact — fleet scoring compares these instead of the f64
/// [`normalized_cost`] to keep score ordering bit-reproducible.
pub fn normalized_cost_fixed(alms: u32, dsps: u32) -> u64 {
    alms as u64 + dsps as u64 * DSP_ALM_EQUIVALENT_U64
}

/// Fixed-point normalized cost of a configuration (exact integer twin
/// of [`config_cost`]).
pub fn config_cost_fixed(cfg: &EgpuConfig) -> u64 {
    let r = ResourceReport::for_config(cfg);
    normalized_cost_fixed(r.alms, r.dsps)
}

/// The Table 1 power-performance-area metric, normalized so the eGPU row
/// is 1: cost / Fmax relative to the eGPU's cost / Fmax. Lower is better.
pub fn ppa_metric(luts: f64, dsps: f64, fmax_mhz: f64) -> f64 {
    let egpu = EGPU_TABLE1;
    let rel_cost = normalized_cost(luts as u32, dsps as u32)
        / normalized_cost(egpu.luts as u32, egpu.dsps as u32);
    let rel_speed = egpu.fmax_mhz / fmax_mhz;
    rel_cost * rel_speed
}

/// One Table 1 comparison row.
#[derive(Debug, Clone, Copy)]
pub struct Table1Row {
    pub arch: &'static str,
    pub config: &'static str,
    pub luts: u32,
    pub dsps: u32,
    pub fmax_mhz: f64,
    pub device: &'static str,
}

/// Published datapoints the paper compares against (Table 1).
pub const TABLE1_PUBLISHED: [Table1Row; 3] = [
    Table1Row {
        arch: "FGPU",
        config: "2CUx8PE",
        luts: 57_000,
        dsps: 48,
        fmax_mhz: 250.0,
        device: "Zynq-7000",
    },
    Table1Row {
        arch: "DO-GPU",
        config: "4CUx8PE",
        luts: 360_000,
        dsps: 1344,
        fmax_mhz: 208.0,
        device: "Stratix 10",
    },
    Table1Row {
        arch: "FlexGrip",
        config: "1SMx16PE",
        luts: 114_000,
        dsps: 300,
        fmax_mhz: 100.0,
        device: "Virtex-6",
    },
];

/// The paper's eGPU Table 1 row (small DP instance).
pub const EGPU_TABLE1: Table1Row = Table1Row {
    arch: "eGPU",
    config: "1SMx16SP",
    luts: 5_000,
    dsps: 24,
    fmax_mhz: 771.0,
    device: "Agilex",
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::MemoryMode;

    #[test]
    fn dsp_equivalent_is_100_alms() {
        assert_eq!(normalized_cost(1000, 3), 1300.0);
    }

    #[test]
    fn nios_cost_matches_paper() {
        // §7: Nios "consumed 1100 ALMs (plus 3 DSP Blocks, giving a
        // normalized cost of 1400)".
        assert_eq!(normalized_cost(1100, 3), 1400.0);
    }

    #[test]
    fn benchmark_configs_cost_5_to_6x_nios() {
        // §7: "eGPU is 5× to 6× larger than Nios" — with the reported
        // costs exactly; the model-derived cost stays the same order.
        assert!((BENCH_COST_DP / BENCH_COST_NIOS - 5.3).abs() < 0.1);
        assert!((BENCH_COST_DOT / BENCH_COST_NIOS - 6.4).abs() < 0.1);
        let nios = BENCH_COST_NIOS;
        let dp = config_cost(&EgpuConfig::benchmark(MemoryMode::Dp, false));
        let dot = config_cost(&EgpuConfig::benchmark(MemoryMode::Dp, true));
        assert!(
            (4.0..=9.0).contains(&(dp / nios)),
            "model DP/Nios = {:.1}",
            dp / nios
        );
        assert!(dot > dp, "dot core must add cost");
    }

    #[test]
    fn fixed_point_cost_is_exactly_the_float_cost() {
        // Resource counts are far below 2^53, the DSP weight is a
        // whole number, and u64→f64 is exact in that range — so the
        // fixed-point cost must equal the f64 cost bit-for-bit on
        // every configuration we model.
        for memory in [MemoryMode::Dp, MemoryMode::Qp] {
            for dot in [false, true] {
                let cfg = EgpuConfig::benchmark(memory, dot);
                assert_eq!(config_cost_fixed(&cfg) as f64, config_cost(&cfg));
            }
        }
        assert_eq!(normalized_cost_fixed(1100, 3) as f64, normalized_cost(1100, 3));
    }

    #[test]
    fn ppa_orders_of_magnitude() {
        // Table 1: eGPU PPA = 1; others 36–175 (one to two OOM worse).
        let egpu = ppa_metric(
            EGPU_TABLE1.luts as f64,
            EGPU_TABLE1.dsps as f64,
            EGPU_TABLE1.fmax_mhz,
        );
        assert!((egpu - 1.0).abs() < 1e-9);
        for row in TABLE1_PUBLISHED {
            let p = ppa_metric(row.luts as f64, row.dsps as f64, row.fmax_mhz);
            assert!(
                (20.0..=250.0).contains(&p),
                "{}: PPA {p:.0} not 1-2 OOM worse",
                row.arch
            );
        }
    }
}
