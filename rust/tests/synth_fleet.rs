//! The `egpu::synth` contract (ISSUE 6 acceptance):
//!
//! - the synthesized fleet fits the budget (independently re-summed
//!   `ResourceReport`s) and every config in it is placeable;
//! - the winning fleet round-trips through `sim::config_json` into a
//!   `serve --configs`-style fleet bit-identically, and serving through
//!   the parsed configs reproduces serving through the originals;
//! - its SLO-met throughput dominates both homogeneous demo-fleet
//!   baselines on the demo trace;
//! - the search result is bit-identical across reruns and under
//!   sequential vs parallel serving;
//! - frontier scoring is bit-identical at any `jobs` value (PR 8), and
//!   dominance pruning is winner-preserving — same fleet and score,
//!   never more replays;
//! - infeasible candidates are rejected with the placer's reason, not
//!   silently skipped.

use std::sync::Arc;

use egpu::api::{
    synthesize, AreaBudget, FleetBuilder, KernelCache, Server, SynthOptions, SynthResult,
};
use egpu::harness::loadgen::{demo_requests, heavy_tail_requests, BurstSpec, LoadSpec};
use egpu::harness::Rng;
use egpu::model::resources::ResourceReport;
use egpu::place;
use egpu::serve::Request;
use egpu::sim::{config_json, EgpuConfig, MemoryMode};
use egpu::synth::candidate_space;

/// The acceptance budget: roomier than `AreaBudget::demo()` so the
/// search has multi-core compositions to choose between.
fn budget() -> AreaBudget {
    AreaBudget { alms: 48_000, dsps: 144, m20ks: 1_400 }
}

/// The demo trace the acceptance criterion names: the reference
/// serving workload, small enough to keep hundreds of scoring replays
/// cheap.
fn demo_trace() -> Vec<Request> {
    demo_requests(&LoadSpec::demo(10))
}

fn serve_fleet(cfgs: &[EgpuConfig], trace: &[Request], sequential: bool) -> u64 {
    let mut fleet = FleetBuilder::new();
    for cfg in cfgs {
        fleet = fleet.core(cfg.clone());
    }
    let served = Server::builder()
        .fleet(fleet)
        .sequential(sequential)
        .build()
        .and_then(|mut s| s.serve(trace.to_vec()));
    match served {
        Ok(report) => {
            let t = &report.telemetry;
            t.completed.saturating_sub(t.deadline_missed)
        }
        // A fleet that cannot serve the trace at all earns zero.
        Err(_) => 0,
    }
}

#[test]
fn synthesized_fleet_fits_places_dominates_and_round_trips() {
    let budget = budget();
    let trace = demo_trace();
    let opts = SynthOptions { max_cores: 4, ..SynthOptions::default() };
    let result = synthesize(&budget, &trace, &opts).expect("synthesis must find a fleet");
    assert!(!result.fleet.is_empty());
    assert!(result.fleet.len() <= opts.max_cores);

    // Budget fit, re-summed independently of the synth accounting.
    let (mut alms, mut dsps, mut m20ks) = (0u64, 0u64, 0u64);
    for cfg in &result.fleet {
        let r = ResourceReport::for_config(cfg);
        alms += r.alms as u64;
        dsps += r.dsps as u64;
        m20ks += r.m20ks as u64;
    }
    assert!(
        alms <= budget.alms && dsps <= budget.dsps && m20ks <= budget.m20ks,
        "fleet needs {alms}/{dsps}/{m20ks} against {budget}"
    );
    assert_eq!((result.usage.alms, result.usage.dsps, result.usage.m20ks), (alms, dsps, m20ks));

    // Every core is placeable hardware.
    for cfg in &result.fleet {
        place::place(cfg).unwrap_or_else(|e| panic!("{}: {e}", cfg.name));
    }

    // Round trip: the emitted fleet JSON parses back bit-identically …
    let parsed = config_json::configs_from_json(&result.fleet_json())
        .expect("emitted fleet JSON must parse");
    assert_eq!(parsed, result.fleet, "fleet must round-trip through config_json");

    // … and a `serve --configs`-style server over the parsed configs
    // reproduces serving over the originals exactly (full ServeReport).
    let serve_via = |cfgs: &[EgpuConfig]| {
        let mut fleet = FleetBuilder::new();
        for cfg in cfgs {
            fleet = fleet.core(cfg.clone());
        }
        Server::builder()
            .fleet(fleet)
            .build()
            .unwrap()
            .serve(trace.clone())
            .expect("the synthesized fleet must serve the demo trace")
    };
    assert_eq!(serve_via(&parsed), serve_via(&result.fleet));

    // Dominates both homogeneous demo-fleet baselines, recomputed here
    // from scratch: as many copies of each demo config as the budget
    // admits (capped at the same max_cores), served the same way.
    let mut demo_cfgs: Vec<EgpuConfig> = Vec::new();
    for cfg in FleetBuilder::demo_mixed().as_configs() {
        if !demo_cfgs.iter().any(|c| c.name == cfg.name) {
            demo_cfgs.push(cfg.clone());
        }
    }
    assert_eq!(demo_cfgs.len(), 2, "the demo fleet mixes two config shapes");
    for cfg in &demo_cfgs {
        let r = ResourceReport::for_config(cfg);
        let mut k = 0usize;
        while k < opts.max_cores {
            let n = (k + 1) as u64;
            if r.alms as u64 * n > budget.alms
                || r.dsps as u64 * n > budget.dsps
                || r.m20ks as u64 * n > budget.m20ks
            {
                break;
            }
            k += 1;
        }
        assert!(k > 0, "{} must fit the acceptance budget at least once", cfg.name);
        let baseline = serve_fleet(&vec![cfg.clone(); k], &trace, false);
        assert!(
            result.score.slo_met >= baseline,
            "synthesized fleet ({} SLO-met) must dominate {k}x {} ({baseline} SLO-met)",
            result.score.slo_met,
            cfg.name
        );
        // The result's own baseline records agree with the recompute.
        let recorded = result
            .baselines
            .iter()
            .find(|b| b.name == cfg.name)
            .unwrap_or_else(|| panic!("no baseline record for {}", cfg.name));
        assert_eq!(recorded.cores, k);
        assert_eq!(recorded.slo_met, baseline);
    }
}

#[test]
fn search_is_bit_identical_across_reruns_and_dispatch_modes() {
    // A restricted candidate set keeps three full searches cheap; the
    // determinism contract is the same as over the full space.
    // Stride 3 over the 5-tier enumeration so the subset still mixes
    // feature tiers (plain/pred/dot/full), not just one tier.
    let cands: Vec<EgpuConfig> = candidate_space().into_iter().step_by(3).collect();
    assert!(cands.len() >= 6);
    let budget = budget();
    let trace = heavy_tail_requests(&BurstSpec::demo(8));
    let opts = SynthOptions { max_cores: 3, candidates: cands, ..SynthOptions::default() };

    let a = synthesize(&budget, &trace, &opts).expect("restricted synthesis must succeed");
    let b = synthesize(&budget, &trace, &opts).expect("rerun must succeed");
    assert_eq!(a, b, "same inputs must give a bit-identical SynthResult");

    let seq = SynthOptions { sequential: true, ..opts };
    let c: SynthResult = synthesize(&budget, &trace, &seq).expect("sequential must succeed");
    // Sequential vs parallel serving may not perturb the search: the
    // score is modeled bus cycles, not wall time.
    assert_eq!(a.fleet, c.fleet);
    assert_eq!(a.score, c.score);
    assert_eq!((a.completed, a.shed, a.deadline_missed), (c.completed, c.shed, c.deadline_missed));
    assert_eq!(a.evaluated, c.evaluated);
}

#[test]
fn parallel_scoring_is_bit_identical_across_jobs_and_reruns() {
    // The full SynthResult — winner, score, usage, baselines, rejects
    // AND the evaluated count — must not depend on how many scoring
    // workers replay the frontier, nor on the run.
    let cands: Vec<EgpuConfig> = candidate_space().into_iter().step_by(3).collect();
    let budget = budget();
    let trace = heavy_tail_requests(&BurstSpec::demo(8));
    let base = SynthOptions { max_cores: 3, candidates: cands, ..SynthOptions::default() };

    let one = synthesize(&budget, &trace, &SynthOptions { jobs: 1, ..base.clone() })
        .expect("jobs=1 synthesis must succeed");
    let four = synthesize(&budget, &trace, &SynthOptions { jobs: 4, ..base.clone() })
        .expect("jobs=4 synthesis must succeed");
    let again = synthesize(&budget, &trace, &SynthOptions { jobs: 4, ..base })
        .expect("jobs=4 rerun must succeed");
    assert_eq!(one, four, "jobs=4 must be bit-identical to the sequential scorer");
    assert_eq!(four, again, "jobs=4 must be bit-identical across reruns");
}

#[test]
fn pruning_preserves_the_winner_on_randomized_budgets_and_seeds() {
    // Property: across randomized area budgets and trace seeds,
    // dominance pruning never changes the winning fleet or its
    // FleetScore — it only skips replays, so `evaluated` can only
    // shrink (or tie). Feasibility (Err vs Ok) must agree too.
    let cands: Vec<EgpuConfig> = candidate_space().into_iter().step_by(4).collect();
    let mut rng = Rng::new(0x5EED_D011);
    for case in 0..4 {
        let budget = AreaBudget {
            alms: 24_000 + rng.below(30_000) as u64,
            dsps: 64 + rng.below(96) as u64,
            m20ks: 700 + rng.below(700) as u64,
        };
        let trace = heavy_tail_requests(&BurstSpec {
            seed: rng.next_u64(),
            ..BurstSpec::demo(6)
        });
        let base = SynthOptions {
            max_cores: 3,
            candidates: cands.clone(),
            jobs: 2,
            ..SynthOptions::default()
        };
        let on = synthesize(&budget, &trace, &SynthOptions { prune: true, ..base.clone() });
        let off = synthesize(&budget, &trace, &SynthOptions { prune: false, ..base });
        match (on, off) {
            (Ok(on), Ok(off)) => {
                assert_eq!(
                    on.fleet, off.fleet,
                    "case {case} ({budget}): pruning changed the winner"
                );
                assert_eq!(
                    on.score, off.score,
                    "case {case} ({budget}): pruning changed the score"
                );
                assert_eq!(
                    (on.completed, on.shed, on.deadline_missed),
                    (off.completed, off.shed, off.deadline_missed),
                    "case {case} ({budget}): pruning changed the winner's serve card"
                );
                assert!(
                    on.evaluated <= off.evaluated,
                    "case {case} ({budget}): pruning performed {} replays, unpruned {}",
                    on.evaluated,
                    off.evaluated
                );
            }
            (on, off) => assert_eq!(
                on.is_err(),
                off.is_err(),
                "case {case} ({budget}): pruning changed feasibility"
            ),
        }
    }
}

#[test]
fn infeasible_candidates_are_rejected_with_reasons() {
    // A config the resource model accepts but the placer refuses:
    // 2544 threads of 16 registers under DP needs 16368 modeled ALMs —
    // inside a 16400-ALM sector — but its LAB demand (1673) overflows
    // the sector's 1640 LABs. Deliberately knife-edge against the
    // calibrated model constants; the preconditions below fail first
    // (with a clear message) if recalibration ever moves it.
    let unplaceable = EgpuConfig {
        name: "lab-overflow".into(),
        threads: 2544,
        regs_per_thread: 16,
        shared_kb: 2,
        predicate_levels: 16,
        ..EgpuConfig::default()
    };
    unplaceable.validate().expect("fixture must be a valid config");
    assert!(
        place::place(&unplaceable).is_err(),
        "fixture must overflow the sector's LABs (model recalibrated?)"
    );

    // A config that fits no 20k-ALM budget: maximum static scale-up.
    let mut oversized = EgpuConfig::benchmark(MemoryMode::Dp, true);
    oversized.name = "oversized".into();
    oversized.threads = 4096;
    oversized.regs_per_thread = 64;
    oversized.shared_kb = 512;
    oversized.predicate_levels = 8;

    // The demo fleet's DP core: fits, places, serves everything.
    let mut good = EgpuConfig::benchmark(MemoryMode::Dp, true);
    good.name = "good".into();
    good.predicate_levels = 8;

    let budget = AreaBudget { alms: 20_000, dsps: 64, m20ks: 2_000 };
    let fixture = ResourceReport::for_config(&unplaceable);
    assert!(
        (fixture.alms as u64) <= budget.alms,
        "fixture must pass the budget gate to reach the placer"
    );

    let opts = SynthOptions {
        candidates: vec![unplaceable.clone(), oversized.clone(), good.clone()],
        max_cores: 2,
        ..SynthOptions::default()
    };
    let trace = demo_trace();
    let result = synthesize(&budget, &trace, &opts).expect("the good candidate must win");

    assert!(result.fleet.iter().all(|c| c.name == "good"));
    let reason_of = |name: &str| {
        result
            .rejected
            .iter()
            .find(|r| r.name == name)
            .unwrap_or_else(|| panic!("{name} must be rejected"))
            .reason
            .clone()
    };
    assert!(
        reason_of("lab-overflow").starts_with("placement:"),
        "placer refusals must carry the placer's reason, got: {}",
        reason_of("lab-overflow")
    );
    assert!(
        reason_of("oversized").contains("exceeds the budget"),
        "budget refusals must name the shortfall, got: {}",
        reason_of("oversized")
    );
}

#[test]
fn heavy_tail_trace_serves_through_the_demo_fleet() {
    let trace = heavy_tail_requests(&BurstSpec::demo(16));
    let offered = trace.len();
    let cache: Arc<KernelCache> = KernelCache::shared();
    let report = Server::builder()
        .kernel_cache(cache)
        .build()
        .unwrap()
        .serve(trace)
        .expect("the demo fleet must serve the heavy-tail trace");
    assert_eq!(report.submitted(), offered);
    assert_eq!(
        report.telemetry.completed + report.telemetry.shed,
        offered as u64,
        "every offered request must be accounted for"
    );
}
