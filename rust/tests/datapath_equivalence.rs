//! Native ↔ XLA datapath equivalence: the proof that the AOT-compiled
//! python/JAX/Pallas artifacts implement the same machine as the rust
//! lanes. Whole programs run on both backends; architectural state is
//! compared bit-exactly for integer ops and exactly (or to f32 rounding
//! for reduction/rsqrt order differences) for FP.
//!
//! Requires `artifacts/` (run `make artifacts`); tests skip otherwise so
//! `cargo test` works on a fresh checkout.

use egpu::asm::assemble;
use egpu::datapath::xla::XlaDatapath;
use egpu::runtime::default_artifacts_dir;
use egpu::sim::{EgpuConfig, Machine, MemoryMode};

fn artifacts_available() -> bool {
    default_artifacts_dir().join("opmap.json").is_file()
}

fn cfg() -> EgpuConfig {
    let mut c = EgpuConfig::benchmark(MemoryMode::Dp, true);
    c.predicate_levels = 8;
    c
}

fn machine_native() -> Machine {
    Machine::new(cfg()).unwrap()
}

fn machine_xla() -> Machine {
    let be = XlaDatapath::new(default_artifacts_dir(), cfg().wavefronts()).unwrap();
    Machine::with_backend(cfg(), Some(Box::new(be))).unwrap()
}

/// Run the same program + seeded state on both backends, return both
/// machines for state comparison.
fn run_both(src: &str, seed: impl Fn(&mut Machine)) -> (Machine, Machine) {
    let mut n = machine_native();
    let mut x = machine_xla();
    for m in [&mut n, &mut x] {
        let p = assemble(src, m.cfg.word_layout()).unwrap();
        m.load_program(p).unwrap();
        seed(m);
        m.run(10_000_000).unwrap();
    }
    (n, x)
}

fn assert_regs_equal(n: &Machine, x: &Machine, reg: u8) {
    for t in 0..512 {
        assert_eq!(
            n.regs().read_thread(t, reg),
            x.regs().read_thread(t, reg),
            "thread {t} r{reg}: native {:#x} xla {:#x}",
            n.regs().read_thread(t, reg),
            x.regs().read_thread(t, reg)
        );
    }
}

#[test]
fn fp_ops_bit_exact() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let src = "
        fadd r2, r0, r1
        fsub r3, r0, r1
        fmul r4, r0, r1
        fmax r5, r2, r3
        fmin r6, r2, r3
        fneg r7, r4
        fabs r8, r7
        invsqr r9, r8
        stop
    ";
    // Seed r0/r1 with normal-range f32 values (XLA CPU flushes denormals,
    // so denormal inputs are excluded by design — documented in DESIGN.md).
    let (n, x) = run_both(src, |m| {
        for t in 0..512usize {
            let a = (t as f32 * 0.37 - 40.0).max(0.5);
            let b = t as f32 * -1.93 + 11.5;
            m.regs_mut().write_thread(t, 0, a.to_bits());
            m.regs_mut().write_thread(t, 1, b.to_bits());
        }
    });
    for r in 2..=9u8 {
        assert_regs_equal(&n, &x, r);
    }
}

#[test]
fn int_ops_bit_exact() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let src = "
        tdx r0
        ldi r1, #0x31
        nop
        nop
        nop
        nop
        nop
        nop
        mul16lo.i32 r2, r0, r1
        mul16hi.i32 r3, r0, r1
        mul24lo.i32 r4, r0, r2
        mul24hi.i32 r5, r0, r2
        and r6, r2, r4
        or r7, r2, r4
        xor r8, r2, r4
        not r9, r2
        cnot r10, r2
        bvs r11, r0
        shl.u32 r12, r0, r1
        shr.u32 r13, r9, r1
        shr.i32 r14, r9, r1
        pop r15, r9
        max.i32 r16, r2, r9
        min.i32 r17, r2, r9
        max.u32 r18, r2, r9
        min.u32 r19, r2, r9
        add.i32 r20, r2, r9
        sub.i32 r21, r2, r9
        neg.i32 r22, r2
        abs.i32 r23, r21
        stop
    ";
    let (n, x) = run_both(src, |_| {});
    for r in 2..=23u8 {
        assert_regs_equal(&n, &x, r);
    }
}

#[test]
fn predicated_program_state_matches() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let src = "
        tdx r0
        ldi r1, #100
        nop
        nop
        nop
        nop
        nop
        nop
        if.lt.i32 r0, r1
        add.i32 r2, r0, r0
        else
        sub.i32 r2, r0, r1
        endif
        stop
    ";
    let (n, x) = run_both(src, |_| {});
    assert_regs_equal(&n, &x, 2);
    assert_eq!(n.cycles(), x.cycles(), "cycle counts must be identical");
}

#[test]
fn dynamic_narrowing_matches() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let src = "
        tdx r0
        ldi r1, #7
        nop
        nop
        nop
        nop
        nop
        nop
        [w4,dhalf] add.i32 r2, r0, r1
        [w1,d0]    add.i32 r3, r0, r1
        [w16,dquart] xor r4, r0, r1
        stop
    ";
    let (n, x) = run_both(src, |_| {});
    for r in 2..=4u8 {
        assert_regs_equal(&n, &x, r);
    }
}

#[test]
fn dot_and_sum_match_to_f32_rounding() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let src = "
        tdx r0
        dot r2, r0, r0
        sum r3, r0, r1
        stop
    ";
    let (n, x) = run_both(src, |_| {});
    let nd = f32::from_bits(n.regs().read_thread(0, 2));
    let xd = f32::from_bits(x.regs().read_thread(0, 2));
    // tid values are tiny denormal bit patterns; sums are exact here, but
    // allow rounding-order slack for generality.
    assert!(
        (nd - xd).abs() <= nd.abs() * 1e-5 + f32::MIN_POSITIVE,
        "dot: native {nd} xla {xd}"
    );
    let ns = f32::from_bits(n.regs().read_thread(0, 3));
    let xs = f32::from_bits(x.regs().read_thread(0, 3));
    assert!(
        (ns - xs).abs() <= ns.abs() * 1e-5 + f32::MIN_POSITIVE,
        "sum: native {ns} xla {xs}"
    );
}

#[test]
fn shared_memory_program_identical() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // Transpose-flavoured kernel: every thread writes a computed address.
    let src = "
        tdx r0
        ldi r1, #3
        nop
        nop
        nop
        nop
        nop
        nop
        xor r2, r0, r1
        sto r0, (r2)+1024
        lod r3, (r2)+1024
        stop
    ";
    let (n, x) = run_both(src, |_| {});
    for a in 1024..1536u32 {
        assert_eq!(
            n.shared().read(a).unwrap(),
            x.shared().read(a).unwrap(),
            "shared[{a}]"
        );
    }
    assert_regs_equal(&n, &x, 3);
    assert_eq!(n.cycles(), x.cycles());
}
