//! Property-based tests over the assembler, ISA codec, scheduler and
//! simulator (hand-rolled generators — proptest is unavailable offline;
//! `harness::Rng` provides seeded, reproducible randomness).
//!
//! Invariants exercised with hundreds of random cases each:
//!  - instruction-word encode/decode is a bijection on valid encodings
//!  - disassemble → reassemble reproduces identical words
//!  - Sched-generated random programs are hazard-free and their cycle
//!    estimate equals the simulator's count exactly
//!  - simulation is deterministic
//!  - the issue-plan executor (`Machine::run`) and the retained
//!    reference interpreter (`Machine::run_reference`) produce
//!    bit-identical registers/shared memory, identical cycle counts and
//!    identical hazard totals
//!  - the superplan (fused-trace) path agrees with both of those on
//!    predicated, control-heavy and budget-stopped random programs,
//!    including partial stats when the budget expires mid-trace
//!  - dynamic narrowing touches exactly the selected thread prefix
//!  - random configurations either validate and boot, or error cleanly

use egpu::asm::{assemble, disassemble};
use egpu::harness::Rng;
use egpu::isa::{DepthSel, Instr, Opcode, TType, ThreadCtrl, WidthSel, WordLayout};
use egpu::kernels::sched::Sched;
use egpu::sim::{EgpuConfig, Machine, MemoryMode, RunStats, PIPELINE_DEPTH};

fn random_tc(rng: &mut Rng) -> ThreadCtrl {
    let w = *rng.choose(&[WidthSel::All16, WidthSel::Quarter4, WidthSel::Sp0]);
    let d = *rng.choose(&[
        DepthSel::Wave0,
        DepthSel::All,
        DepthSel::Half,
        DepthSel::Quarter,
    ]);
    ThreadCtrl::new(w, d)
}

#[test]
fn word_encode_decode_bijection() {
    let mut rng = Rng::new(0x1337);
    for regs in [16usize, 32, 64] {
        let layout = WordLayout::for_regs(regs);
        for _ in 0..2000 {
            let op = Opcode::from_bits(rng.below(Opcode::COUNT) as u8).unwrap();
            let mut i = Instr::new(op);
            i.tc = random_tc(&mut rng);
            i.ttype = *rng.choose(&[TType::Int, TType::Uint, TType::Fp32]);
            let maxr = layout.max_reg() as usize;
            i.rd = rng.below(maxr + 1) as u8;
            i.ra = rng.below(maxr + 1) as u8;
            i.rb = rng.below(maxr + 1) as u8;
            // IF stores a condition code in imm[2:0]; keep it valid.
            i.imm = if op == Opcode::If {
                rng.below(6) as u16
            } else {
                rng.next_u32() as u16
            };
            let w = layout.encode(&i);
            let d = layout.decode(w).unwrap_or_else(|e| panic!("{op:?}: {e:?}"));
            assert_eq!(d, i, "layout {regs} regs");
        }
    }
}

#[test]
fn disassemble_reassemble_fixpoint() {
    let mut rng = Rng::new(0xD15A);
    let layout = WordLayout::for_regs(32);
    for _ in 0..200 {
        let src = random_program_source(&mut rng, 30);
        let p = assemble(&src, layout).unwrap_or_else(|e| panic!("{e}\n{src}"));
        // The listing form (`disassemble`) prefixes addresses for humans;
        // strip them for the reassembly fixpoint.
        let listing = disassemble(&p.words, layout).unwrap();
        let dis: String = listing
            .lines()
            .map(|l| {
                let t = l.trim_start();
                let t = t.split_once(':').map(|(_, rest)| rest).unwrap_or(t);
                format!("{}\n", t.trim())
            })
            .collect();
        let p2 = assemble(&dis, layout).unwrap_or_else(|e| panic!("{e}\n{dis}"));
        assert_eq!(p.words, p2.words, "\noriginal:\n{src}\ndisasm:\n{dis}");
    }
}

/// Random straight-line source: ALU ops over r0..r7, loads/stores through
/// the thread-id register, random thread-space annotations. Uses Sched so
/// the program is hazard-free by construction.
fn random_sched(rng: &mut Rng, threads: usize, len: usize) -> Sched {
    let mut s = Sched::new("prop", threads, WordLayout::for_regs(32), MemoryMode::Dp);
    s.op("tdx r0");
    for _ in 0..len {
        let tc = random_tc(rng);
        let rd = 1 + rng.below(7);
        let ra = rng.below(8);
        let rb = rng.below(8);
        let line = match rng.below(10) {
            0 => format!("{tc} add.i32 r{rd}, r{ra}, r{rb}"),
            1 => format!("{tc} sub.u32 r{rd}, r{ra}, r{rb}"),
            2 => format!("{tc} xor r{rd}, r{ra}, r{rb}"),
            3 => format!("{tc} max.i32 r{rd}, r{ra}, r{rb}"),
            4 => format!("{tc} fadd r{rd}, r{ra}, r{rb}"),
            5 => format!("{tc} fmul r{rd}, r{ra}, r{rb}"),
            6 => format!("{tc} ldi r{rd}, #{}", rng.range_i64(-100, 100)),
            7 => format!("{tc} shr.u32 r{rd}, r{ra}, r{rb}"),
            8 => format!("{tc} lod r{rd}, (r0)+{}", rng.below(64) * 8),
            _ => format!("{tc} sto r{rd}, (r0)+{}", 2048 + rng.below(64) * 8),
        };
        s.op(line);
    }
    s
}

fn random_program_source(rng: &mut Rng, len: usize) -> String {
    let mut s = random_sched(rng, 512, len);
    s.fence();
    s.finish()
}

#[test]
fn sched_programs_hazard_free_and_estimate_exact() {
    let mut rng = Rng::new(0x5EED);
    for case in 0..150 {
        let threads = *rng.choose(&[16usize, 64, 256, 512]);
        let len = 5 + rng.below(40);
        let mut s = random_sched(&mut rng, threads, len);
        let est = s.estimated_cycles() + 1; // + stop
        let src = s.finish();

        let cfg = EgpuConfig::benchmark(MemoryMode::Dp, false);
        let mut m = Machine::new(cfg.clone()).unwrap();
        let p = assemble(&src, cfg.word_layout()).unwrap();
        m.load_program(p).unwrap();
        m.set_threads(threads).unwrap();
        let stats = m.run(10_000_000).unwrap();
        assert_eq!(
            stats.hazards, 0,
            "case {case} (threads {threads}): {:?}\n{src}",
            stats.hazard_samples
        );
        assert_eq!(
            stats.cycles,
            est + PIPELINE_DEPTH,
            "case {case}: estimate mismatch\n{src}"
        );
    }
}

/// Architectural state + stats after a run, for cross-path comparison.
fn machine_state(m: &Machine, stats: RunStats) -> (RunStats, Vec<u32>, Vec<u32>) {
    let regs: Vec<u32> = (0..512)
        .flat_map(|t| (0..8u8).map(move |r| (t, r)))
        .map(|(t, r)| m.regs().read_thread(t, r))
        .collect();
    let mem: Vec<u32> = m.shared().read_block(0, 4096).to_vec();
    (stats, regs, mem)
}

/// Random programs with predicates, narrowing, extension ops and
/// unscheduled hazards (the hazard *totals* must match across executors,
/// they need not be zero). Addresses stay within [0, 4096) so no run
/// faults.
fn random_mixed_source(rng: &mut Rng, len: usize) -> String {
    let mut src = String::from("tdx r0\n");
    let mut depth = 0usize;
    for _ in 0..len {
        let tc = random_tc(rng);
        let rd = 1 + rng.below(7);
        let ra = rng.below(8);
        let rb = rng.below(8);
        match rng.below(14) {
            0 => src.push_str(&format!("{tc} add.i32 r{rd}, r{ra}, r{rb}\n")),
            1 => src.push_str(&format!("{tc} fmul r{rd}, r{ra}, r{rb}\n")),
            2 => src.push_str(&format!("{tc} max.u32 r{rd}, r{ra}, r{rb}\n")),
            3 => src.push_str(&format!("{tc} shr.i32 r{rd}, r{ra}, r{rb}\n")),
            4 => src.push_str(&format!("{tc} neg.i32 r{rd}, r{ra}\n")),
            5 => src.push_str(&format!("{tc} ldi r{rd}, #{}\n", rng.range_i64(-512, 512))),
            6 => src.push_str(&format!("{tc} lod r{rd}, (r0)+{}\n", rng.below(32) * 8)),
            7 => src.push_str(&format!("{tc} sto r{rd}, (r0)+{}\n", 1024 + rng.below(32) * 8)),
            8 => src.push_str(&format!("{tc} dot r{rd}, r{ra}, r{rb}\n")),
            9 => src.push_str(&format!("{tc} sum r{rd}, r{ra}, r{rb}\n")),
            10 => src.push_str(&format!("{tc} invsqr r{rd}, r{ra}\n")),
            11 if depth < 5 => {
                src.push_str(&format!("if.lt.u32 r{ra}, r{rb}\n"));
                depth += 1;
            }
            12 if depth > 0 => src.push_str("else\n"),
            13 if depth > 0 => {
                src.push_str("endif\n");
                depth -= 1;
            }
            _ => src.push_str("nop\n"),
        }
    }
    for _ in 0..depth {
        src.push_str("endif\n");
    }
    src.push_str("stop\n");
    src
}

/// Random control-heavy source: a counted `init`/`loop` body of random
/// straight-line ops with embedded `jsr` calls, a `jmp` over dead code,
/// and a subroutine — every superplan boundary kind (control
/// transfers, branch targets) in one program.
fn random_control_source(rng: &mut Rng, len: usize) -> String {
    let mut src = String::from("tdx r0\nldi r1, #3\n");
    src.push_str(&format!("init #{}\n", 1 + rng.below(4)));
    src.push_str("body:\n");
    for _ in 0..len {
        let rd = 1 + rng.below(7);
        let ra = rng.below(8);
        let rb = rng.below(8);
        match rng.below(6) {
            0 => src.push_str(&format!("add.i32 r{rd}, r{ra}, r{rb}\n")),
            1 => src.push_str(&format!("fadd r{rd}, r{ra}, r{rb}\n")),
            2 => src.push_str(&format!("ldi r{rd}, #{}\n", rng.range_i64(-64, 64))),
            3 => src.push_str(&format!("lod r{rd}, (r0)+{}\n", rng.below(16) * 8)),
            4 => src.push_str(&format!("sto r{rd}, (r0)+{}\n", 1024 + rng.below(16) * 8)),
            _ => src.push_str("jsr sub\n"),
        }
    }
    src.push_str("loop body\n");
    src.push_str("jmp end\n");
    // Dead by fallthrough, but a fusable run the compiler still traces.
    src.push_str("add.i32 r1, r1, r1\nadd.i32 r2, r2, r2\n");
    src.push_str("sub:\nadd.i32 r3, r0, r1\nxor r4, r3, r0\nrts\n");
    src.push_str("end:\nadd.i32 r5, r1, r2\nstop\n");
    src
}

#[test]
fn superplan_path_matches_plan_path_and_reference() {
    // Three-way parity: the fused superplan path (`run` default), the
    // per-instruction plan path (`set_superplans(false)`) and the
    // reference interpreter agree bit-for-bit on registers, shared
    // memory, cycles, hazards and the whole profile.
    let mut rng = Rng::new(0x5B9A);
    let mut cfg = EgpuConfig::default();
    cfg.dot_core = true;
    cfg.sfu = true;
    for case in 0..60 {
        let src = match case % 3 {
            0 => random_program_source(&mut rng, 25),
            1 => random_mixed_source(&mut rng, 30),
            _ => random_control_source(&mut rng, 12),
        };
        let prog = assemble(&src, cfg.word_layout()).unwrap_or_else(|e| panic!("{e}\n{src}"));

        let mut fused = Machine::new(cfg.clone()).unwrap();
        fused.load_program(prog.clone()).unwrap();
        let sf = fused
            .run(10_000_000)
            .unwrap_or_else(|e| panic!("fused: {e}\n{src}"));

        let mut plan = Machine::new(cfg.clone()).unwrap();
        plan.load_program(prog.clone()).unwrap();
        plan.set_superplans(false);
        let sp = plan
            .run(10_000_000)
            .unwrap_or_else(|e| panic!("plan: {e}\n{src}"));

        let mut reference = Machine::new(cfg.clone()).unwrap();
        reference.load_program(prog).unwrap();
        let sr = reference
            .run_reference(10_000_000)
            .unwrap_or_else(|e| panic!("reference: {e}\n{src}"));

        let f = machine_state(&fused, sf);
        assert_eq!(
            f,
            machine_state(&plan, sp),
            "case {case}: fused vs per-instruction plan\n{src}"
        );
        assert_eq!(
            f,
            machine_state(&reference, sr),
            "case {case}: fused vs reference\n{src}"
        );
    }
}

#[test]
fn budget_stops_mid_trace_match_plan_path_and_reference() {
    // A cycle budget can expire in the middle of a fused trace: the
    // fused path must fall back to per-instruction stepping and stop at
    // exactly the same pc, with exactly the same partial stats and
    // architectural state, as the unfused paths.
    let mut rng = Rng::new(0xB06E7);
    let mut cfg = EgpuConfig::default();
    cfg.dot_core = true;
    cfg.sfu = true;
    for case in 0..12 {
        let src = match case % 3 {
            0 => random_program_source(&mut rng, 20),
            1 => random_mixed_source(&mut rng, 24),
            _ => random_control_source(&mut rng, 10),
        };
        let prog = assemble(&src, cfg.word_layout()).unwrap_or_else(|e| panic!("{e}\n{src}"));
        let total = {
            let mut m = Machine::new(cfg.clone()).unwrap();
            m.load_program(prog.clone()).unwrap();
            m.run(u64::MAX).unwrap_or_else(|e| panic!("{e}\n{src}")).cycles
        };
        for budget in [
            1u64,
            7,
            total / 5 + 1,
            total / 2 + 1,
            total.saturating_sub(PIPELINE_DEPTH + 1).max(1),
        ] {
            let run_mode = |mode: u8| {
                let mut m = Machine::new(cfg.clone()).unwrap();
                m.load_program(prog.clone()).unwrap();
                if mode == 1 {
                    m.set_superplans(false);
                }
                let r = if mode == 2 {
                    m.run_reference(budget)
                } else {
                    m.run(budget)
                };
                match r {
                    Ok(stats) => (None, machine_state(&m, stats)),
                    Err(e) => {
                        let partial = e
                            .partial
                            .as_deref()
                            .expect("cycle-limit stops carry partial stats")
                            .clone();
                        (Some((e.pc, e.message.clone())), machine_state(&m, partial))
                    }
                }
            };
            let fused = run_mode(0);
            assert_eq!(
                fused,
                run_mode(1),
                "case {case} budget {budget}: fused vs per-instruction plan\n{src}"
            );
            assert_eq!(
                fused,
                run_mode(2),
                "case {case} budget {budget}: fused vs reference\n{src}"
            );
        }
    }
}

#[test]
fn planned_executor_matches_reference_interpreter() {
    // Tentpole invariant: compiling IssuePlans at decode time changes the
    // simulator's speed, never its semantics. Compare the planned hot
    // loop against the retained per-instruction interpreter on random
    // programs — bit-identical registers and shared memory, identical
    // cycle counts, identical hazard totals (and the whole profile).
    let mut rng = Rng::new(0x91A7);
    let mut cfg = EgpuConfig::default(); // 32 KB shared, predicates on
    cfg.dot_core = true;
    cfg.sfu = true;
    for case in 0..80 {
        let src = if case % 2 == 0 {
            random_program_source(&mut rng, 25)
        } else {
            random_mixed_source(&mut rng, 30)
        };
        let prog = assemble(&src, cfg.word_layout()).unwrap_or_else(|e| panic!("{e}\n{src}"));

        let mut planned = Machine::new(cfg.clone()).unwrap();
        planned.load_program(prog.clone()).unwrap();
        let sp = planned
            .run(10_000_000)
            .unwrap_or_else(|e| panic!("planned: {e}\n{src}"));

        let mut reference = Machine::new(cfg.clone()).unwrap();
        reference.load_program(prog).unwrap();
        let sr = reference
            .run_reference(10_000_000)
            .unwrap_or_else(|e| panic!("reference: {e}\n{src}"));

        assert_eq!(
            machine_state(&planned, sp),
            machine_state(&reference, sr),
            "case {case}: planned and reference executors diverge\n{src}"
        );
    }
}

#[test]
fn planned_executor_matches_reference_with_hazards_off() {
    // The verified-program fast path skips hazard bookkeeping in both
    // executors identically.
    let mut rng = Rng::new(0x0FF);
    let cfg = EgpuConfig::benchmark(MemoryMode::Dp, false);
    for _ in 0..20 {
        let src = random_program_source(&mut rng, 20);
        let prog = assemble(&src, cfg.word_layout()).unwrap();
        let mut planned = Machine::new(cfg.clone()).unwrap();
        planned.load_program(prog.clone()).unwrap();
        planned.set_hazard_checking(false);
        let sp = planned.run(10_000_000).unwrap();
        let mut reference = Machine::new(cfg.clone()).unwrap();
        reference.load_program(prog).unwrap();
        reference.set_hazard_checking(false);
        let sr = reference.run_reference(10_000_000).unwrap();
        assert_eq!(machine_state(&planned, sp), machine_state(&reference, sr), "{src}");
    }
}

#[test]
fn simulation_deterministic() {
    let mut rng = Rng::new(0xDE7);
    for _ in 0..30 {
        let src = random_program_source(&mut rng, 25);
        let cfg = EgpuConfig::benchmark(MemoryMode::Dp, false);
        let run = || {
            let mut m = Machine::new(cfg.clone()).unwrap();
            let p = assemble(&src, cfg.word_layout()).unwrap();
            m.load_program(p).unwrap();
            m.run(10_000_000).unwrap();
            let regs: Vec<u32> = (0..512)
                .flat_map(|t| (0..8u8).map(move |r| (t, r)))
                .map(|(t, r)| m.regs().read_thread(t, r))
                .collect();
            let mem: Vec<u32> = m.shared().read_block(2048, 1024).to_vec();
            (m.cycles(), regs, mem)
        };
        assert_eq!(run(), run(), "\n{src}");
    }
}

#[test]
fn narrowing_touches_exactly_the_selected_prefix() {
    let mut rng = Rng::new(0xA11);
    let cfg = EgpuConfig::default();
    for _ in 0..200 {
        let tc = random_tc(&mut rng);
        let src = format!("ldi r1, #7\n{tc} ldi r1, #9\nstop\n");
        let mut m = Machine::new(cfg.clone()).unwrap();
        let p = assemble(&src, cfg.word_layout()).unwrap();
        m.load_program(p).unwrap();
        m.run(10_000).unwrap();
        let total_waves = cfg.wavefronts();
        for wave in 0..total_waves {
            for sp in 0..16 {
                let want = if tc.selects(sp, wave, total_waves) { 9 } else { 7 };
                assert_eq!(
                    m.regs().read_thread(wave * 16 + sp, 1),
                    want,
                    "{tc} wave {wave} sp {sp}"
                );
            }
        }
    }
}

#[test]
fn stores_gate_on_selection_loads_charge_ports() {
    // Cycle-charge property: for random subsets, LOD charges
    // ceil(selected/4) and STO charges ceil(selected/wports).
    let mut rng = Rng::new(0xC4A6);
    for _ in 0..100 {
        let tc = random_tc(&mut rng);
        let memory = *rng.choose(&[MemoryMode::Dp, MemoryMode::Qp]);
        let cfg = EgpuConfig::benchmark(memory, false);
        let mut m = Machine::new(cfg.clone()).unwrap();
        let src = format!("tdx r0\n{tc} lod r1, (r0)+0\n{tc} sto r1, (r0)+1024\nstop\n");
        let p = assemble(&src, cfg.word_layout()).unwrap();
        m.load_program(p).unwrap();
        let stats = m.run(100_000).unwrap();
        let waves = tc.depth.waves(cfg.wavefronts());
        let sel = (waves * tc.width.lanes()) as u64;
        let expect = 32 // tdx
            + sel.div_ceil(4).max(1)
            + sel.div_ceil(memory.write_ports() as u64).max(1)
            + 1 // stop
            + PIPELINE_DEPTH;
        assert_eq!(stats.cycles, expect, "{tc} {memory:?}");
    }
}

#[test]
fn random_configs_validate_or_reject_consistently() {
    let mut rng = Rng::new(0xCF6);
    for _ in 0..500 {
        let mut cfg = EgpuConfig::default();
        cfg.threads = rng.below(80) * 16; // 0 invalid, rest valid
        cfg.regs_per_thread = *rng.choose(&[8usize, 16, 32, 48, 64]);
        cfg.shared_kb = rng.below(600);
        cfg.alu_precision = *rng.choose(&[8u8, 16, 32]);
        cfg.shift_precision = *rng.choose(&[1u8, 4, 16, 32]);
        cfg.predicate_levels = rng.below(40);
        let valid = cfg.validate().is_ok();
        let expect = cfg.threads > 0
            && cfg.threads % 16 == 0
            && matches!(cfg.regs_per_thread, 16 | 32 | 64)
            && (2..=512).contains(&cfg.shared_kb)
            && matches!(cfg.alu_precision, 16 | 32)
            && matches!(cfg.shift_precision, 1 | 16 | 32)
            && cfg.shift_precision <= cfg.alu_precision
            && cfg.predicate_levels <= 32;
        assert_eq!(valid, expect, "{cfg:?}");
        // Machines only boot from valid configurations.
        assert_eq!(Machine::new(cfg.clone()).is_ok(), valid);
    }
}

#[test]
fn predicate_nesting_random_walks() {
    // Random IF/ELSE/ENDIF walks never corrupt non-predicated registers
    // and always restore full-width execution after the stack empties.
    let mut rng = Rng::new(0x9E57);
    let mut cfg = EgpuConfig::default();
    cfg.predicate_levels = 8;
    for _ in 0..50 {
        let mut src = String::from("tdx r0\nldi r1, #256\nldi r2, #0\n");
        let mut depth = 0usize;
        for _ in 0..rng.below(12) {
            match rng.below(3) {
                0 if depth < 8 => {
                    src.push_str("if.lt.u32 r0, r1\n");
                    depth += 1;
                }
                1 if depth > 0 => src.push_str("else\n"),
                _ if depth > 0 => {
                    src.push_str("endif\n");
                    depth -= 1;
                }
                _ => src.push_str("nop\n"),
            }
        }
        for _ in 0..depth {
            src.push_str("endif\n");
        }
        // After all predicates pop, a full-width op must hit every thread.
        src.push_str("ldi r3, #42\nstop\n");
        let mut m = Machine::new(cfg.clone()).unwrap();
        let p = assemble(&src, cfg.word_layout()).unwrap();
        m.load_program(p).unwrap();
        m.run(100_000).unwrap_or_else(|e| panic!("{e}\n{src}"));
        for t in [0usize, 255, 256, 511] {
            assert_eq!(m.regs().read_thread(t, 3), 42, "thread {t}\n{src}");
        }
    }
}

#[test]
fn unbalanced_predicates_fault() {
    let mut cfg = EgpuConfig::default();
    cfg.predicate_levels = 2;
    let layout = cfg.word_layout();
    // Overflow: 3 nested IFs on a 2-level stack.
    let mut m = Machine::new(cfg.clone()).unwrap();
    let src = "tdx r0\nldi r1, #9\nnop\nnop\nnop\nnop\nnop\nnop\n\
               if.lt.u32 r0, r1\nif.lt.u32 r0, r1\nif.lt.u32 r0, r1\nstop\n";
    let p = assemble(src, layout).unwrap();
    m.load_program(p).unwrap();
    assert!(m.run(10_000).is_err(), "predicate overflow must fault");
    // Underflow: ENDIF with empty stack.
    let mut m = Machine::new(cfg).unwrap();
    let p = assemble("endif\nstop\n", layout).unwrap();
    m.load_program(p).unwrap();
    assert!(m.run(10_000).is_err(), "predicate underflow must fault");
}
