//! Paper-vs-model assertions for every table in the evaluation:
//! Table 1 (PPA), Tables 4/5 (fitting results), Table 6 (integer ALU),
//! Tables 7/8 (benchmark cycles, via the suite), and the §7 headline
//! claims (OOM vs Nios, FlexGrip ~31x, QP trade-offs, dot-core gains,
//! 4.7% bus overhead).

use egpu::baseline::flexgrip;
use egpu::baseline::nios::{NIOS_ALMS, NIOS_DSPS, NIOS_MHZ};
use egpu::harness::{paper_cycles, suite, within_band, Variant};
use egpu::model::alu_model::{alu_cost, TABLE6};
use egpu::model::cost::{self, normalized_cost, ppa_metric, TABLE1_PUBLISHED};
use egpu::model::frequency::FrequencyReport;
use egpu::model::resources::ResourceReport;
use egpu::sim::{EgpuConfig, IntAluClass, MemoryMode};

// -------------------------------------------------------------------
// Table 1
// -------------------------------------------------------------------

#[test]
fn table1_ppa_orders_of_magnitude() {
    // §2: "an power-performance-area (PPA) metric which is one or two
    // orders of magnitude (OOM) smaller than some of the earlier soft
    // GPGPUs". Paper's column: FGPU 36, DO-GPU 133, FlexGrip 175, eGPU 1.
    let paper = [("FGPU", 36.0), ("DO-GPU", 133.0), ("FlexGrip", 175.0)];
    for (row, (name, p)) in TABLE1_PUBLISHED.iter().zip(paper) {
        assert_eq!(row.arch, name);
        let m = ppa_metric(row.luts as f64, row.dsps as f64, row.fmax_mhz);
        assert!(
            within_band(m, p, 2.0),
            "{name}: PPA {m:.0} vs paper {p} (cost-model difference too large)"
        );
        assert!(m > 10.0, "{name} must be at least an OOM worse than eGPU");
    }
}

// -------------------------------------------------------------------
// Tables 4 and 5
// -------------------------------------------------------------------

#[test]
fn table4_resources_within_15_percent() {
    // Paper Table 4 ALM/FF per row.
    let paper: [(u32, u32, u32, u32); 6] = [
        (4243, 13635, 24, 50),
        (7518, 18992, 24, 98),
        (7579, 19155, 24, 131),
        (9754, 25425, 24, 131),
        (10127, 26040, 32, 195),
        (10697, 26618, 32, 259),
    ];
    for (cfg, (alm, ff, dsp, m20k)) in EgpuConfig::table4_presets().iter().zip(paper) {
        let r = ResourceReport::for_config(cfg);
        assert!(
            within_band(r.alms as f64, alm as f64, 1.15),
            "{}: ALM {} vs paper {alm}",
            cfg.name,
            r.alms
        );
        assert!(
            within_band(r.registers as f64, ff as f64, 1.15),
            "{}: FF {} vs paper {ff}",
            cfg.name,
            r.registers
        );
        assert_eq!(r.dsps, dsp, "{}: DSP", cfg.name);
        assert_eq!(r.m20ks, m20k, "{}: M20K", cfg.name);
    }
}

#[test]
fn table5_resources_within_15_percent() {
    let paper: [(u32, u32, u32, u32); 4] = [
        (5468, 14487, 24, 99),
        (7057, 16722, 32, 131),
        (11314, 25050, 32, 131),
        (10174, 23094, 32, 195),
    ];
    for (cfg, (alm, ff, dsp, m20k)) in EgpuConfig::table5_presets().iter().zip(paper) {
        let r = ResourceReport::for_config(cfg);
        assert!(
            within_band(r.alms as f64, alm as f64, 1.15),
            "{}: ALM {} vs paper {alm}",
            cfg.name,
            r.alms
        );
        assert!(
            within_band(r.registers as f64, ff as f64, 1.15),
            "{}: FF {} vs paper {ff}",
            cfg.name,
            r.registers
        );
        assert_eq!(r.dsps, dsp, "{}: DSP", cfg.name);
        // Table 5 row 1 is 98 in the text's formula but 99 in the table;
        // accept ±1 block.
        assert!(
            (r.m20ks as i64 - m20k as i64).abs() <= 1,
            "{}: M20K {} vs paper {m20k}",
            cfg.name,
            r.m20ks
        );
    }
}

#[test]
fn all_configs_close_at_embedded_limit() {
    // §6: "a soft processor can consistently close timing at a level
    // limited only by the embedded features" — every preset's soft logic
    // beats the embedded Fmax, so the core closes at 771 (DP) / 600 (QP).
    for cfg in EgpuConfig::table4_presets().iter().chain(EgpuConfig::table5_presets().iter()) {
        let f = FrequencyReport::for_config(cfg);
        assert!(f.closes_at_embedded_limit, "{}: soft {} < embedded {}", cfg.name, f.soft_mhz, f.embedded_mhz);
        let want = if cfg.memory == MemoryMode::Dp { 771.0 } else { 600.0 };
        assert_eq!(f.core_mhz, want, "{}", cfg.name);
        assert!(f.soft_mhz > f.core_mhz, "{}", cfg.name);
    }
}

// -------------------------------------------------------------------
// Table 6
// -------------------------------------------------------------------

#[test]
fn table6_matches_paper_exactly() {
    let paper = [
        (16u8, "Min", 90u32, 136u32),
        (16, "Small", 134, 207),
        (16, "Full", 199, 269),
        (32, "Min", 208, 406),
        (32, "Full", 394, 704),
    ];
    assert_eq!(TABLE6.len(), paper.len());
    for (a, (prec, class, alm, ff)) in TABLE6.iter().zip(paper) {
        assert_eq!(a.precision, prec);
        assert_eq!(a.class.name(), class);
        assert_eq!(a.alms, alm, "{prec}-bit {class}");
        assert_eq!(a.regs, ff, "{prec}-bit {class}");
    }
}

#[test]
fn alu_cost_resolution() {
    // §5.2 scaling claims: full 16-bit ≈ 2x min; 32-bit full ≈ 2x ALMs,
    // ~3x registers vs 16-bit full.
    let mut cfg = EgpuConfig::default();
    cfg.alu_precision = 16;
    cfg.int_alu = IntAluClass::Min;
    cfg.shift_precision = 1;
    let min16 = alu_cost(&cfg);
    cfg.int_alu = IntAluClass::Full;
    cfg.shift_precision = 16;
    let full16 = alu_cost(&cfg);
    cfg.alu_precision = 32;
    cfg.shift_precision = 32;
    let full32 = alu_cost(&cfg);
    assert!(within_band(full16.alms as f64, 2.0 * min16.alms as f64, 1.25));
    assert!(within_band(full32.alms as f64, 2.0 * full16.alms as f64, 1.25));
    assert!(within_band(full32.regs as f64, 2.6 * full16.regs as f64, 1.25));
}

// -------------------------------------------------------------------
// Tables 7 and 8 + §7 claims
// -------------------------------------------------------------------

#[test]
fn tables7_and_8_cycles_within_band() {
    for b in suite::Benchmark::ALL {
        for &dim in b.dims() {
            let r = suite::run(b, dim);
            for (m, v) in [
                (Some(&r.nios), Variant::Nios),
                (Some(&r.dp), Variant::Dp),
                (Some(&r.qp), Variant::Qp),
                (r.dot.as_ref(), Variant::Dot),
            ] {
                let (Some(m), Some(p)) = (m, paper_cycles(b, dim, v)) else {
                    continue;
                };
                if v == Variant::Nios {
                    // Nios: two-sided 4x band (coarse CPI model; the
                    // paper's Nios reduction scales superlinearly with n).
                    assert!(
                        within_band(m.cycles as f64, p as f64, 4.0),
                        "{b:?}-{dim} {}: {} vs paper {p}",
                        v.label(),
                        m.cycles
                    );
                } else {
                    // eGPU variants: ≤ paper + tolerance only. The kernel
                    // compiler's list scheduler may legitimately beat the
                    // paper's hand schedules, so being fast is a pass,
                    // not a regression; the paper value stays in the
                    // message as the reference point.
                    assert!(
                        (m.cycles as f64) <= p as f64 * 2.0,
                        "{b:?}-{dim} {}: {} exceeds paper {p} + tolerance",
                        v.label(),
                        m.cycles
                    );
                }
            }
        }
    }
}

#[test]
fn egpu_beats_nios_by_an_oom_on_time() {
    // §7: "we see at least an OOM performance difference based on time"
    // for the larger benchmarks; small dims are allowed to be lower.
    let mut oom = 0usize;
    let mut total = 0usize;
    for b in suite::Benchmark::ALL {
        for &dim in b.dims() {
            let r = suite::run(b, dim);
            let ratio = r.ratio_time(Variant::Nios).unwrap();
            assert!(ratio > 3.0, "{b:?}-{dim}: only {ratio:.1}x faster than Nios");
            total += 1;
            if ratio >= 10.0 {
                oom += 1;
            }
        }
    }
    assert!(
        oom * 2 >= total,
        "OOM speedup in only {oom}/{total} instances"
    );
}

#[test]
fn normalized_efficiency_still_favors_egpu() {
    // §7: "is still better on an area normalized basis" — Nios normalized
    // > 1 in almost every instance.
    let mut wins = 0usize;
    let mut total = 0usize;
    for b in suite::Benchmark::ALL {
        for &dim in b.dims() {
            let r = suite::run(b, dim);
            total += 1;
            if r.normalized(Variant::Nios).unwrap() > 1.0 {
                wins += 1;
            }
        }
    }
    assert!(wins + 2 >= total, "eGPU area-normalized win in only {wins}/{total}");
}

#[test]
fn dot_core_multiplies_the_advantage() {
    // §8: "When we add the dot product core ... the advantage can
    // increase again by several times."
    for b in [suite::Benchmark::Reduction, suite::Benchmark::Mmm] {
        for &dim in b.dims() {
            let r = suite::run(b, dim);
            let dot = r.ratio_cycles(Variant::Dot).unwrap();
            assert!(dot < 0.55, "{b:?}-{dim}: dot/dp cycle ratio {dot:.2}");
        }
    }
}

#[test]
fn qp_trades_frequency_for_write_bandwidth() {
    // Table 7/8 pattern: QP needs fewer cycles on write-heavy kernels
    // (transpose, bitonic, FFT) but similar on reduction; its *time* is
    // usually no better because of the 600 vs 771 MHz clock.
    for (b, dim) in [
        (suite::Benchmark::Transpose, 64),
        (suite::Benchmark::Bitonic, 128),
        (suite::Benchmark::Fft, 128),
    ] {
        let r = suite::run(b, dim);
        let rc = r.ratio_cycles(Variant::Qp).unwrap();
        assert!(rc < 0.9, "{b:?}-{dim}: QP cycle ratio {rc:.2}");
        let rt = r.ratio_time(Variant::Qp).unwrap();
        assert!(rt > rc, "{b:?}-{dim}: clock penalty must show in time");
    }
    let red = suite::run(suite::Benchmark::Reduction, 64);
    assert!(red.ratio_time(Variant::Qp).unwrap() > 1.0);
}

#[test]
fn flexgrip_comparison_on_mmm() {
    // §7: FlexGrip underperforms eGPU by ~31x averaged on cycles; the
    // MMM rows give 19.2 / 36.8 / 188.3.
    for (n, paper_ratio) in flexgrip::MMM_CYCLE_RATIO_VS_EGPU {
        let r = suite::run(suite::Benchmark::Mmm, n);
        let fg = flexgrip::mmm_cycles(n).unwrap();
        let measured_ratio = fg as f64 / r.dp.cycles as f64;
        assert!(
            within_band(measured_ratio, paper_ratio, 2.0),
            "MMM-{n}: FlexGrip/eGPU = {measured_ratio:.1} vs paper {paper_ratio}"
        );
    }
}

#[test]
fn nios_cost_model_matches_paper() {
    // §7: Nios IIe consumed 1100 ALMs + 3 DSP = normalized 1400 @347 MHz.
    assert_eq!(normalized_cost(NIOS_ALMS, NIOS_DSPS), cost::BENCH_COST_NIOS);
    assert_eq!(NIOS_MHZ, 347.0);
    // Benchmark configuration costs: "7400, 8400, and 9000 ALMs for the
    // eGPU-DP, eGPU-QP, and eGPU-Dot".
    assert!(cost::BENCH_COST_DP < cost::BENCH_COST_QP);
    assert!(cost::BENCH_COST_QP < cost::BENCH_COST_DOT);
}

#[test]
fn bus_overhead_near_paper_average() {
    // §7: "The performance impact was only 4.7%, averaged over all
    // benchmarks" — replicated with the coordinator's 32-bit bus model
    // over the full suite's data-movement footprints.
    use egpu::coordinator::{aggregate_bus_overhead, Coordinator, Job};
    use egpu::kernels::{bitonic, f32_bits, fft, mmm, reduction, transpose};

    let mut jobs: Vec<(EgpuConfig, Job)> = Vec::new();
    let base = EgpuConfig::benchmark(MemoryMode::Dp, false);
    for n in [32usize, 64, 128] {
        let v: Vec<f32> = (0..n).map(|i| i as f32).collect();
        jobs.push((
            base.clone(),
            Job::new(reduction::reduction(n)).load(0, f32_bits(&v)).unload(n, 1),
        ));
        let m: Vec<u32> = (0..(n * n) as u32).collect();
        jobs.push((
            base.clone(),
            Job::new(transpose::transpose(n)).load(0, m.clone()).unload(n * n, n * n),
        ));
        jobs.push((
            mmm::config(n, MemoryMode::Dp, false),
            Job::new(mmm::mmm(n))
                .load(0, f32_bits(&vec![1.0; n * n]))
                .load(n * n, f32_bits(&vec![0.5; n * n]))
                .unload(2 * n * n, n * n),
        ));
    }
    for n in [32usize, 64, 128, 256] {
        let v: Vec<u32> = (0..n as u32).rev().collect();
        jobs.push((
            EgpuConfig::benchmark_predicated(MemoryMode::Dp),
            Job::new(bitonic::bitonic(n)).load(0, v).unload(0, n),
        ));
        let re: Vec<f32> = (0..n).map(|i| (i as f32 * 0.3).cos()).collect();
        let im = vec![0f32; n];
        let mut j = Job::new(fft::fft(n)).unload(0, 2 * n);
        for (b, d) in fft::shared_init(&re, &im) {
            j = j.load(b, d);
        }
        jobs.push((base.clone(), j));
    }

    let mut results = Vec::new();
    for (cfg, job) in jobs {
        let mut c = Coordinator::new(cfg, 1).unwrap();
        c.submit(job);
        results.extend(c.run_all().unwrap());
    }
    let avg = aggregate_bus_overhead(&results);
    // Paper: 4.7% averaged over all benchmarks. The aggregate is
    // time-weighted (MMM dominates and amortizes its DMA); accept 1%-10%.
    assert!(
        (0.01..=0.10).contains(&avg),
        "aggregate bus overhead {:.1}% vs paper 4.7%",
        avg * 100.0
    );
}

// -------------------------------------------------------------------
// Resource-model bands and monotonicity (ISSUE 6 satellite)
// -------------------------------------------------------------------

#[test]
fn table45_presets_land_in_the_paper_bands() {
    // §1 claims "a logic range – depending on the configuration – of 4k
    // to 10k ALMs"; the Table 4/5 rows themselves stretch slightly past
    // both ends (Large-QP-1 is 11314 ALMs in Table 5, Large-DP-2 is 259
    // M20Ks in Table 4), so the asserted band is the paper's own rows
    // ±8% model tolerance, and the headline 4k/10k envelope is checked
    // as "the extremes get close to it", not as a hard clip.
    let mut alms = Vec::new();
    let mut m20ks = Vec::new();
    for cfg in EgpuConfig::table4_presets().iter().chain(EgpuConfig::table5_presets().iter()) {
        let r = ResourceReport::for_config(cfg);
        assert!(
            (3_600..=11_500).contains(&r.alms),
            "{}: {} ALMs outside the Table 4/5 band",
            cfg.name,
            r.alms
        );
        assert!(
            (24..=32).contains(&r.dsps),
            "{}: {} DSPs outside the paper's 24-32 band",
            cfg.name,
            r.dsps
        );
        assert!(
            (47..=262).contains(&r.m20ks),
            "{}: {} M20Ks outside the Table 4/5 band",
            cfg.name,
            r.m20ks
        );
        alms.push(r.alms);
        m20ks.push(r.m20ks);
    }
    // The presets must actually exercise the envelope, not huddle in
    // the middle: a ~4k-ALM small core and a ~10k-ALM large core, a
    // ~50-M20K row and a ~250-M20K row.
    assert!(alms.iter().min().unwrap() < &5_000);
    assert!(alms.iter().max().unwrap() > &9_500);
    assert!(m20ks.iter().min().unwrap() < &60);
    assert!(m20ks.iter().max().unwrap() > &190);
}

#[test]
fn resource_model_is_monotone_on_the_verified_axes() {
    // Growing a single config axis never shrinks a resource count —
    // scoped to the (axis, resource) pairs that are provably monotone
    // under the calibrated model. The excluded pairs are genuinely
    // non-monotone, not untested: the least-squares ALM/FF fit carries
    // negative interaction corrections (regs32/regs64, per-shared-KB),
    // so ALMs can shrink when regs or shared grow; and under QP the
    // 2048×8 minimum-geometry rule can *halve* regfile M20Ks when
    // threads cross the 2047-word boundary (pinned below).
    use egpu::harness::Rng;

    const THREADS: [usize; 8] = [16, 32, 64, 128, 256, 512, 1024, 2048];
    const REGS: [usize; 3] = [16, 32, 64];
    const SHARED: [usize; 9] = [2, 4, 8, 16, 32, 64, 128, 256, 512];

    let mut rng = Rng::new(0x5CA1E);
    for _ in 0..200 {
        let cfg = EgpuConfig {
            name: "sample".into(),
            threads: *rng.choose(&THREADS),
            regs_per_thread: *rng.choose(&REGS),
            shared_kb: *rng.choose(&SHARED),
            memory: if rng.chance(0.5) { MemoryMode::Qp } else { MemoryMode::Dp },
            predicate_levels: *rng.choose(&[0usize, 2, 8, 16]),
            dot_core: rng.chance(0.5),
            sfu: rng.chance(0.5),
            ..EgpuConfig::default()
        };
        cfg.validate().expect("sampled configs are valid by construction");
        let base = ResourceReport::for_config(&cfg);

        // Threads axis: ALMs, FFs and DSPs never shrink in any mode
        // (the per-thread predicate terms and the QP wide-column DSP
        // rule only grow); M20Ks only under DP (see the QP pin below).
        if cfg.threads < 2048 {
            let mut up = cfg.clone();
            up.threads *= 2;
            let r = ResourceReport::for_config(&up);
            assert!(r.alms >= base.alms, "{:?} threads x2 shrank ALMs", cfg);
            assert!(r.registers >= base.registers, "{:?} threads x2 shrank FFs", cfg);
            assert!(r.dsps >= base.dsps, "{:?} threads x2 shrank DSPs", cfg);
            if cfg.memory == MemoryMode::Dp {
                assert!(r.m20ks >= base.m20ks, "{:?} threads x2 shrank M20Ks", cfg);
            }
        }

        // Registers axis: M20Ks and DSPs never shrink (the regfile
        // doubles before the QP halving rule can apply, and wider
        // register columns only add integer-multiply DSPs).
        if cfg.regs_per_thread < 64 {
            let mut up = cfg.clone();
            up.regs_per_thread *= 2;
            let r = ResourceReport::for_config(&up);
            assert!(r.m20ks >= base.m20ks, "{:?} regs x2 shrank M20Ks", cfg);
            assert!(r.dsps >= base.dsps, "{:?} regs x2 shrank DSPs", cfg);
        }

        // Shared-memory axis: M20Ks never shrink, DSPs are untouched.
        if cfg.shared_kb < 512 {
            let mut up = cfg.clone();
            up.shared_kb *= 2;
            let r = ResourceReport::for_config(&up);
            assert!(r.m20ks >= base.m20ks, "{:?} shared x2 shrank M20Ks", cfg);
            assert_eq!(r.dsps, base.dsps, "{:?} shared x2 changed DSPs", cfg);
        }
    }

    // The documented QP exception, pinned exactly: at 64 regs/thread,
    // growing threads 496 → 512 crosses the 2047-word minimum-geometry
    // boundary (496·64/16 = 1984 ≤ 2047 < 2048 = 512·64/16), so the
    // regfile drops from the DP count (124) to half the larger DP
    // count (64) and total M20Ks shrink. This is the paper's §5.1 QP
    // rule, not a model bug — and it is why the property above scopes
    // the threads axis to DP for M20Ks.
    let mut qp = EgpuConfig {
        memory: MemoryMode::Qp,
        regs_per_thread: 64,
        threads: 496,
        ..EgpuConfig::default()
    };
    let below = ResourceReport::for_config(&qp);
    qp.threads = 512;
    let above = ResourceReport::for_config(&qp);
    assert!(
        above.m20ks < below.m20ks,
        "QP 2047-boundary halving disappeared ({} vs {}) — model changed?",
        above.m20ks,
        below.m20ks
    );
}

#[test]
fn predicates_cost_about_half_more_logic() {
    // §5.3 / Table 4: predicate support "increasing the soft logic
    // resources by about 50%" (Small-DP-1 vs Small-DP-2 also changes the
    // ALU; compare a pure predicate toggle instead).
    let mut without = EgpuConfig::table4_presets()[1].clone();
    without.predicate_levels = 0;
    let with = EgpuConfig::table4_presets()[1].clone();
    let a = ResourceReport::for_config(&without).alms as f64;
    let b = ResourceReport::for_config(&with).alms as f64;
    assert!(
        (1.25..=1.75).contains(&(b / a)),
        "predicates scale ALMs by {:.2}",
        b / a
    );
}
