//! The `egpu::api` redesign invariant: every kernel produces bit-identical
//! outputs and identical cycle counts through `Gpu::launch` as through the
//! legacy `Machine` dance (`new → load_program → set_threads → set_dim_x →
//! run`), and the quickstart flow works end to end (assemble → launch →
//! readback) on both paths.

use egpu::api::{ApiError, Gpu, LaunchReport};
use egpu::harness::Rng;
use egpu::kernels::{bitonic, f32_bits, fft, mmm, reduction, transpose, Kernel};
use egpu::sim::{EgpuConfig, Machine, MemoryMode, RunStats};

/// The pre-redesign execution surface, verbatim.
fn legacy_run(kernel: &Kernel, cfg: &EgpuConfig, init: &[(usize, Vec<u32>)]) -> (RunStats, Machine) {
    let prog = kernel.assemble(cfg).unwrap();
    let mut m = Machine::new(cfg.clone()).unwrap();
    m.load_program(prog).unwrap();
    m.set_threads(kernel.threads).unwrap();
    m.set_dim_x(kernel.dim_x).unwrap();
    for (base, data) in init {
        m.shared_mut().write_block(*base, data);
    }
    let stats = m.run(1_000_000_000).unwrap();
    (stats, m)
}

/// The same work through the unified API.
fn api_run(kernel: &Kernel, cfg: &EgpuConfig, init: &[(usize, Vec<u32>)]) -> (LaunchReport, Machine) {
    let mut gpu = Gpu::new(cfg).unwrap();
    for (base, data) in init {
        gpu.write_words(*base, data).unwrap();
    }
    let report = gpu.launch(kernel).run().unwrap();
    (report, gpu.into_machine())
}

/// Assert full-machine parity: cycle count, instruction count, and the
/// entire shared memory, bit for bit.
fn assert_parity(kernel: &Kernel, cfg: &EgpuConfig, init: &[(usize, Vec<u32>)]) {
    let (stats, legacy) = legacy_run(kernel, cfg, init);
    let (report, api) = api_run(kernel, cfg, init);
    assert_eq!(
        stats.cycles, report.compute_cycles,
        "{}: cycle count diverges between legacy and api paths",
        kernel.name
    );
    assert_eq!(
        stats.instructions, report.stats.instructions,
        "{}: instruction count diverges",
        kernel.name
    );
    let words = cfg.shared_words();
    assert_eq!(
        legacy.shared().read_block(0, words),
        api.shared().read_block(0, words),
        "{}: shared memory diverges",
        kernel.name
    );
}

#[test]
fn reduction_parity() {
    let n = 64;
    let mut rng = Rng::new(0xA11);
    let data: Vec<f32> = (0..n).map(|_| rng.f32_in(-4.0, 4.0)).collect();
    assert_parity(
        &reduction::reduction(n),
        &EgpuConfig::benchmark(MemoryMode::Dp, false),
        &[(0, f32_bits(&data))],
    );
    assert_parity(
        &reduction::reduction_dot(n),
        &EgpuConfig::benchmark(MemoryMode::Dp, true),
        &[(0, f32_bits(&data))],
    );
}

#[test]
fn transpose_parity() {
    let n = 32;
    let mut rng = Rng::new(0xA12);
    let mat: Vec<u32> = (0..n * n).map(|_| rng.next_u32()).collect();
    for mode in [MemoryMode::Dp, MemoryMode::Qp] {
        assert_parity(
            &transpose::transpose_for(n, mode),
            &EgpuConfig::benchmark(mode, false),
            &[(0, mat.clone())],
        );
    }
}

#[test]
fn mmm_parity() {
    let n = 32;
    let mut rng = Rng::new(0xA13);
    let a: Vec<f32> = (0..n * n).map(|_| rng.f32_in(-2.0, 2.0)).collect();
    let b: Vec<f32> = (0..n * n).map(|_| rng.f32_in(-2.0, 2.0)).collect();
    assert_parity(
        &mmm::mmm_for(n, MemoryMode::Dp),
        &mmm::config(n, MemoryMode::Dp, false),
        &[(0, f32_bits(&a)), (n * n, f32_bits(&b))],
    );
}

#[test]
fn bitonic_parity() {
    let n = 64;
    let mut rng = Rng::new(0xA14);
    let data: Vec<u32> = (0..n).map(|_| rng.next_u32() >> 2).collect();
    assert_parity(
        &bitonic::bitonic_for(n, MemoryMode::Dp),
        &EgpuConfig::benchmark_predicated(MemoryMode::Dp),
        &[(0, data)],
    );
}

#[test]
fn fft_parity() {
    let n = 64;
    let mut rng = Rng::new(0xA15);
    let re: Vec<f32> = (0..n).map(|_| rng.f32_in(-1.0, 1.0)).collect();
    let im: Vec<f32> = (0..n).map(|_| rng.f32_in(-1.0, 1.0)).collect();
    assert_parity(
        &fft::fft_for(n, MemoryMode::Dp),
        &EgpuConfig::benchmark(MemoryMode::Dp, false),
        &fft::shared_init(&re, &im),
    );
}

#[test]
fn stream_path_matches_immediate_path() {
    // One job through a 1-core GpuArray produces the same compute cycles
    // and outputs as the immediate Gpu path.
    let n = 64;
    let mut rng = Rng::new(0xA16);
    let data: Vec<f32> = (0..n).map(|_| rng.f32_in(-4.0, 4.0)).collect();
    let cfg = EgpuConfig::benchmark(MemoryMode::Dp, false);

    let mut gpu = Gpu::new(&cfg).unwrap();
    let input = gpu.alloc_at::<f32>(0, n).unwrap();
    let sum = gpu.alloc_at::<f32>(n, 1).unwrap();
    gpu.upload(&input, &data).unwrap();
    let immediate = gpu.launch(&reduction::reduction(n)).run().unwrap();
    let direct = gpu.download(&sum).unwrap()[0];

    let mut array = Gpu::builder().config(cfg).build_array(1).unwrap();
    let s = array.stream();
    array
        .launch_on(&s, reduction::reduction(n))
        .input_f32(0, &data)
        .output(n, 1)
        .submit();
    let reports = array.sync().unwrap();
    assert_eq!(reports.len(), 1);
    assert_eq!(reports[0].compute_cycles, immediate.compute_cycles);
    assert_eq!(reports[0].output_f32(0)[0], direct);
    assert_eq!(reports[0].stream, Some(s.id()));
}

#[test]
fn quickstart_flow_end_to_end() {
    // The quickstart example's flow (assemble → launch → readback) as an
    // integration test, with parity against the legacy Machine path.
    let src = "
        tdx r0
        lod r1, (r0)+0
        fmul r2, r1, r1
        sto r2, (r0)+512
        [w1,d0] ldi r3, #1
        nop
        nop
        nop
        nop
        nop
        [w1,d0] sto r3, (r3)+1023
        stop
    ";
    let xs: Vec<f32> = (0..512).map(|i| i as f32 * 0.5).collect();

    // New API.
    let mut gpu = Gpu::builder().threads(512).shared_kb(32).build().unwrap();
    let input = gpu.alloc_at::<f32>(0, 512).unwrap();
    let squares = gpu.alloc_at::<f32>(512, 512).unwrap();
    let flag = gpu.alloc_at::<u32>(1024, 1).unwrap();
    gpu.upload(&input, &xs).unwrap();
    let report = gpu.launch_asm("square", src).run().unwrap();
    let ys = gpu.download(&squares).unwrap();
    for (x, y) in xs.iter().zip(&ys) {
        assert_eq!(*y, x * x);
    }
    assert_eq!(gpu.download(&flag).unwrap()[0], 1);

    // Legacy path: identical cycles and identical shared state.
    let cfg = EgpuConfig::default();
    let prog = egpu::asm::assemble(src, cfg.word_layout()).unwrap();
    let mut m = Machine::new(cfg.clone()).unwrap();
    m.load_program(prog).unwrap();
    for (i, x) in xs.iter().enumerate() {
        m.shared_mut().write(i as u32, x.to_bits()).unwrap();
    }
    let stats = m.run(1_000_000).unwrap();
    assert_eq!(stats.cycles, report.compute_cycles);
    let words = cfg.shared_words();
    assert_eq!(
        m.shared().read_block(0, words),
        gpu.machine().shared().read_block(0, words)
    );
}

#[test]
fn bus_accounting_counts_every_word_once() {
    let n = 128usize;
    let cfg = EgpuConfig::benchmark(MemoryMode::Dp, false);
    let mut gpu = Gpu::new(&cfg).unwrap();
    let input = gpu.alloc_at::<f32>(0, n).unwrap();
    let sum = gpu.alloc_at::<f32>(n, 1).unwrap();
    let data: Vec<f32> = (0..n).map(|i| i as f32 * 0.25).collect();

    gpu.upload(&input, &data).unwrap();
    let report = gpu.launch(&reduction::reduction(n)).run().unwrap();
    let _ = gpu.download(&sum).unwrap();

    // 1 word per bus cycle (§7): n up, 1 down.
    assert_eq!(report.bus_cycles, n as u64, "upload attributed to launch");
    assert_eq!(gpu.total_bus_cycles(), n as u64 + 1);
    assert_eq!(gpu.total_compute_cycles(), report.compute_cycles);
    assert_eq!(
        gpu.elapsed_cycles(),
        n as u64 + 1 + report.compute_cycles,
        "serial timeline: upload + compute + download"
    );
    assert_eq!(gpu.timeline().len(), 2);
    assert_eq!(report.start, 0);
    assert_eq!(report.end, n as u64 + report.compute_cycles);
    let o = report.bus_overhead();
    assert!(o > 0.0 && o < 1.0, "overhead {o}");
}

#[test]
fn launch_budget_and_builder_validation() {
    let cfg = EgpuConfig::benchmark(MemoryMode::Dp, false);
    // Tiny cycle budget trips the limit.
    let mut gpu = Gpu::new(&cfg).unwrap();
    let data: Vec<f32> = (0..128).map(|i| i as f32).collect();
    gpu.write_words(0, &f32_bits(&data)).unwrap();
    let err = gpu
        .launch(&reduction::reduction(128))
        .max_cycles(10)
        .run()
        .unwrap_err();
    assert!(matches!(err, ApiError::Sim(ref s) if s.message.contains("cycle limit")), "{err}");
    // The budget is enforced before issue and the error keeps the
    // progress made: partial cycles/instructions/profile, not a discard.
    match &err {
        ApiError::Sim(s) => {
            let partial = s.partial.as_ref().expect("cycle-limit error keeps partial stats");
            assert!(partial.cycles >= 10, "budget was 10, got {}", partial.cycles);
            assert!(partial.instructions > 0);
            assert_eq!(partial.profile.total_instructions(), partial.instructions);
        }
        other => panic!("expected a sim error, got {other}"),
    }

    // Invalid static configuration is rejected at build time.
    assert!(Gpu::builder().threads(100).build().is_err());
    assert!(Gpu::builder().regs_per_thread(48).build().is_err());
}

#[test]
fn buffers_are_typed_and_bounds_checked() {
    let cfg = EgpuConfig::benchmark(MemoryMode::Dp, false); // 128 KB = 32768 words
    let mut gpu = Gpu::new(&cfg).unwrap();

    // Bump allocation walks forward; fixed allocation reserves through.
    let a = gpu.alloc::<f32>(100).unwrap();
    let b = gpu.alloc::<i32>(28).unwrap();
    assert_eq!(a.base(), 0);
    assert_eq!(b.base(), 100);

    // Typed roundtrips are bit-exact.
    let fs: Vec<f32> = (0..100).map(|i| i as f32 * -0.5).collect();
    gpu.upload(&a, &fs).unwrap();
    assert_eq!(gpu.download(&a).unwrap(), fs);
    let is: Vec<i32> = (0..28).map(|i| -i).collect();
    gpu.upload(&b, &is).unwrap();
    assert_eq!(gpu.download(&b).unwrap(), is);

    // Length and bounds errors.
    assert!(matches!(
        gpu.upload(&a, &fs[..50]).unwrap_err(),
        ApiError::SizeMismatch { expected: 100, got: 50 }
    ));
    assert!(matches!(
        gpu.alloc_at::<u32>(32768, 1).unwrap_err(),
        ApiError::OutOfMemory { .. }
    ));
    assert!(gpu.write_words(32760, &[0; 16]).is_err());
}
