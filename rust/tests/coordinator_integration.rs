//! Coordinator integration: real kernels through the multi-core dispatch
//! and bus model, including failure injection, a mixed pipeline that
//! chains algorithms over resident data (§7's primary usage mode), and
//! the parallel-dispatch determinism invariant (worker threads change
//! wall-clock only, never the modeled timeline).

use egpu::api::{Gpu, LaunchReport};
use egpu::coordinator::{average_bus_overhead, Coordinator, Job};
use egpu::harness::Rng;
use egpu::kernels::{bitonic, f32_bits, fft, reduction, transpose};
use egpu::sim::{EgpuConfig, MemoryMode};

fn cfg() -> EgpuConfig {
    EgpuConfig::benchmark(MemoryMode::Dp, false)
}

#[test]
fn mixed_workload_across_cores() {
    // Transpose + FFT + reduction batches over 3 cores; every output
    // verified, per-core assignment balanced.
    let mut rng = Rng::new(0x31);
    let mut c = Coordinator::new(cfg(), 3).unwrap();
    let n = 64;

    let mat: Vec<u32> = (0..n * n).map(|_| rng.next_u32()).collect();
    c.submit(Job::new(transpose::transpose(n)).load(0, mat.clone()).unload(n * n, n * n));

    let re: Vec<f32> = (0..n).map(|_| rng.f32_in(-1.0, 1.0)).collect();
    let im = vec![0f32; n];
    let mut fj = Job::new(fft::fft(n)).unload(0, n);
    for (b, d) in fft::shared_init(&re, &im) {
        fj = fj.load(b, d);
    }
    c.submit(fj);

    let vec_: Vec<f32> = (0..n).map(|_| rng.f32_in(-4.0, 4.0)).collect();
    c.submit(Job::new(reduction::reduction(n)).load(0, f32_bits(&vec_)).unload(n, 1));

    let rs = c.run_all().unwrap();
    assert_eq!(rs.len(), 3);
    // Each job on its own core (all were free at submit time).
    let mut cores: Vec<usize> = rs.iter().map(|r| r.core).collect();
    cores.sort_unstable();
    assert_eq!(cores, vec![0, 1, 2]);

    assert_eq!(rs[0].outputs[0], transpose::oracle(&mat, n));
    let (want_r, _) = fft::oracle(&re, &im);
    for k in 0..n {
        let got = f32::from_bits(rs[1].outputs[0][k]) as f64;
        assert!((got - want_r[k]).abs() < 1e-3 * n as f64, "fft bin {k}");
    }
    let got = f32::from_bits(rs[2].outputs[0][0]);
    let want: f32 = vec_.iter().sum();
    assert!((got - want).abs() < want.abs() * 1e-4 + 1e-2);
}

#[test]
fn chained_pipeline_transpose_then_sort_first_column() {
    // Chained "multiple algorithms to the same data": transpose puts
    // column 0 into rows [n², n²+n); a chained bitonic then sorts it.
    // Requires the predicated configuration for the sort.
    let n = 32;
    let pcfg = EgpuConfig::benchmark_predicated(MemoryMode::Dp);
    let mut rng = Rng::new(0x32);
    let mat: Vec<u32> = (0..n * n).map(|_| rng.next_u32() >> 1).collect();

    let mut c = Coordinator::new(pcfg, 2).unwrap();
    c.submit(Job::new(transpose::transpose(n)).load(0, mat.clone()));
    // The transposed matrix lives at [n², 2n²); row 0 of it is the old
    // column 0. Sort it in place — but bitonic sorts at base 0, so sort
    // the *original* matrix's first row instead after the chain proves
    // data residency: use a kernel over [0, n).
    c.submit(Job::new(bitonic::bitonic(n)).unload(0, n).chained());
    let rs = c.run_all().unwrap();
    assert_eq!(rs[0].core, rs[1].core);
    let mut want: Vec<u32> = mat[..n].to_vec();
    want.sort_unstable();
    assert_eq!(rs[1].outputs[0], want, "chained sort of resident row 0");
}

#[test]
fn queue_of_many_jobs_is_stable() {
    let mut c = Coordinator::new(cfg(), 4).unwrap();
    let n = 32;
    let mut wants = Vec::new();
    for i in 0..20 {
        let data: Vec<f32> = (0..n).map(|j| (i * n + j) as f32 * 0.01).collect();
        wants.push(data.iter().sum::<f32>());
        c.submit(Job::new(reduction::reduction(n)).load(0, f32_bits(&data)).unload(n, 1));
    }
    let rs = c.run_all().unwrap();
    assert_eq!(rs.len(), 20);
    // FIFO results match their own inputs (no cross-job contamination).
    for (r, want) in rs.iter().zip(wants) {
        let got = f32::from_bits(r.outputs[0][0]);
        assert!((got - want).abs() < want.abs() * 1e-4 + 1e-2, "{}", r.name);
    }
    // All four cores used.
    let used: std::collections::BTreeSet<usize> = rs.iter().map(|r| r.core).collect();
    assert_eq!(used.len(), 4);
    // Timeline sanity: no job ends before it starts; makespan is the max.
    assert!(rs.iter().all(|r| r.end >= r.start));
    assert_eq!(c.makespan(), rs.iter().map(|r| r.end).max().unwrap());
}

#[test]
fn resident_kernel_reuse_skips_reassembly_and_stays_correct() {
    // The same shared kernel submitted repeatedly to a single core:
    // only the first dispatch assembles and loads the program; every
    // later job reuses the resident machine via an in-place reset. The
    // reset must be complete — each job sees fresh inputs, never a
    // predecessor's registers or shared memory.
    let mut c = Coordinator::new(cfg(), 1).unwrap();
    let n = 64;
    let kernel = std::sync::Arc::new(reduction::reduction(n));
    let mut wants = Vec::new();
    for i in 0..4 {
        let data: Vec<f32> = (0..n).map(|j| (i * n + j) as f32 * 0.125).collect();
        wants.push(data.iter().sum::<f32>());
        c.submit(Job::new_shared(kernel.clone()).load(0, f32_bits(&data)).unload(n, 1));
    }
    let rs = c.run_all().unwrap();
    assert_eq!(rs.len(), 4);
    for (r, want) in rs.iter().zip(wants) {
        let got = f32::from_bits(r.outputs[0][0]);
        assert!(
            (got - want).abs() < want.abs() * 1e-4 + 1e-2,
            "stale machine state leaked into a reused run: {got} vs {want}"
        );
    }
    let reuse = c.reuse_stats();
    assert_eq!(reuse.misses, 1, "one program load for four jobs");
    assert_eq!(reuse.hits, 3);

    // A different kernel evicts the resident program; returning to the
    // first one loads again (the tracker keeps one kernel per core).
    c.submit(Job::new(transpose::transpose(32)).load(0, (0..32 * 32).collect()));
    c.submit(Job::new_shared(kernel.clone()).load(0, f32_bits(&vec![1.0; n])).unload(n, 1));
    c.run_all().unwrap();
    let after = c.reuse_stats();
    assert_eq!(after.misses, 3, "kernel switch must reload");
    assert_eq!(after.hits, 3);
}

#[test]
fn reuse_counters_are_dispatch_mode_invariant() {
    // Submission-order reuse decisions make the counters part of the
    // deterministic observable surface: parallel dispatch must report
    // exactly the sequential numbers.
    let run = |parallel: bool| {
        let mut c = Coordinator::new(cfg(), 2).unwrap();
        c.set_parallel(parallel);
        let n = 64;
        let kernel = std::sync::Arc::new(reduction::reduction(n));
        for i in 0..6 {
            let data: Vec<f32> = (0..n).map(|j| (i + j) as f32).collect();
            c.submit(Job::new_shared(kernel.clone()).load(0, f32_bits(&data)).unload(n, 1));
        }
        let rs = c.run_all().unwrap();
        assert_eq!(rs.len(), 6);
        c.reuse_stats()
    };
    let seq = run(false);
    assert_eq!(seq, run(true));
    assert_eq!(seq.hits + seq.misses, 6);
    assert!(seq.misses <= 2, "at most one load per core");
}

#[test]
fn failure_injection_bad_kernel_surfaces_error() {
    // A kernel whose program faults (OOB store) must return Err from
    // run_all, not corrupt the coordinator. Built from raw asm: compiled
    // kernels carry their lowered program, which `assemble` prefers, so
    // mutating `asm` on one would be ignored.
    let base = reduction::reduction(32);
    let k = egpu::kernels::Kernel::from_asm(
        base.name,
        "ldi r0, #-2\nnop\nnop\nnop\nnop\nnop\nnop\nsto r0, (r0)+0\nstop\n",
        base.threads,
        base.dim_x,
    );
    let mut c = Coordinator::new(cfg(), 1).unwrap();
    c.submit(Job::new(k));
    let err = c.run_all().unwrap_err();
    assert!(err.message.contains("fault"), "{err}");
    // Coordinator still usable afterwards.
    let data: Vec<f32> = (0..32).map(|i| i as f32).collect();
    c.submit(Job::new(reduction::reduction(32)).load(0, f32_bits(&data)).unload(32, 1));
    let rs = c.run_all().unwrap();
    assert!((f32::from_bits(rs[0].outputs[0][0]) - 496.0).abs() < 1e-2);
}

#[test]
fn failure_injection_unsupported_instruction() {
    // DOT on a configuration without the dot core fails at program load.
    let mut c = Coordinator::new(cfg(), 1).unwrap(); // dot_core = false
    c.submit(Job::new(reduction::reduction_dot(32)));
    let err = c.run_all().unwrap_err();
    assert!(err.message.contains("dot-product"), "{err}");
}

#[test]
fn failure_injection_too_many_threads() {
    let mut small = cfg();
    small.threads = 64;
    let mut c = Coordinator::new(small, 1).unwrap();
    c.submit(Job::new(reduction::reduction(128))); // needs 128 threads
    assert!(c.run_all().is_err());
}

#[test]
fn bus_contention_serializes_dma_but_not_compute() {
    // Two big-DMA jobs on two cores: loads must not overlap on the bus,
    // computes may.
    let n = 64;
    let mat: Vec<u32> = (0..n * n).map(|i| i as u32).collect();
    let mut c = Coordinator::new(cfg(), 2).unwrap();
    for _ in 0..2 {
        c.submit(Job::new(transpose::transpose(n)).load(0, mat.clone()).unload(n * n, n * n));
    }
    let rs = c.run_all().unwrap();
    let load = (n * n) as u64;
    // Job 1's load starts exactly after job 0's load (both at t=0 cores).
    assert_eq!(rs[0].start, 0);
    assert_eq!(rs[1].start, load, "second DMA must wait for the bus");
    // But compute overlaps: job 1 ends less than two full serial jobs.
    assert!(rs[1].end < 2 * rs[0].end);
}

#[test]
fn average_overhead_of_empty_batch_is_zero() {
    assert_eq!(average_bus_overhead(&[]), 0.0);
}

// ---------------------------------------------------------------------
// Stream-ordered submission through the `egpu::api` surface.
// ---------------------------------------------------------------------

#[test]
fn two_streams_spread_across_cores_and_stay_ordered() {
    let n = 64;
    let mut rng = Rng::new(0x51);
    let mut array = Gpu::builder().config(cfg()).build_array(2).unwrap();
    let (s0, s1) = (array.stream(), array.stream());
    let mut wants = Vec::new();
    for (i, s) in [(0u64, s0), (1, s1), (2, s0), (3, s1)] {
        let data: Vec<f32> = (0..n).map(|_| rng.f32_in(-2.0, 2.0)).collect();
        wants.push((i, s, data.iter().sum::<f32>()));
        array
            .launch_on(&s, reduction::reduction(n))
            .input_f32(0, &data)
            .output(n, 1)
            .submit();
    }
    let rs = array.sync().unwrap();
    assert_eq!(rs.len(), 4);
    // Each stream stays on one core, the two streams on different cores.
    assert_eq!(rs[0].core, rs[2].core, "stream 0 affinity");
    assert_eq!(rs[1].core, rs[3].core, "stream 1 affinity");
    assert_ne!(rs[0].core, rs[1].core, "streams spread across free cores");
    // Ordered per stream on the shared timeline.
    assert!(rs[2].start >= rs[0].end);
    assert!(rs[3].start >= rs[1].end);
    // Every result matches its own input (no cross-stream contamination).
    for (r, (_, s, want)) in rs.iter().zip(&wants) {
        assert_eq!(r.stream, Some(s.id()));
        let got = r.output_f32(0)[0];
        assert!((got - want).abs() < want.abs() * 1e-4 + 1e-2, "{}", r.name);
    }
}

#[test]
fn chained_launch_on_stream_reuses_resident_data() {
    // Transpose loads the matrix; a chained transpose on the same stream
    // sees it without any input DMA — §7's "multiple algorithms to the
    // same data", expressed as stream affinity instead of keep_data on
    // an implicit last core.
    let n = 32;
    let data: Vec<u32> = (0..(n * n) as u32).collect();
    let mut array = Gpu::builder().config(cfg()).build_array(4).unwrap();
    let s = array.stream();
    array
        .launch_on(&s, transpose::transpose(n))
        .input_words(0, data.clone())
        .submit();
    array
        .launch_on(&s, transpose::transpose(n))
        .output(n * n, n * n)
        .chained()
        .submit();
    let rs = array.sync().unwrap();
    assert_eq!(rs[0].core, rs[1].core, "chained launch must stay on the stream's core");
    assert_eq!(rs[1].bus_cycles, (n * n) as u64, "only the output DMA");
    assert_eq!(rs[1].output_words(0), transpose::oracle(&data, n));
}

#[test]
fn chained_launch_on_fresh_stream_errors() {
    // Regression for the silent chain-onto-core-0 bug: chaining with no
    // resident data is a submission error, surfaced at sync.
    let mut array = Gpu::builder().config(cfg()).build_array(2).unwrap();
    let s = array.stream();
    array
        .launch_on(&s, reduction::reduction(32))
        .chained()
        .submit();
    let err = array.sync().unwrap_err();
    assert!(err.to_string().contains("no resident data"), "{err}");
}

/// The ISSUE-2 determinism contract: interleaved jobs across ≥3 streams
/// on a multi-core `GpuArray` produce identical `JobResult` order,
/// outputs, and bus/compute timelines whether the cores simulate
/// sequentially or on parallel worker threads.
#[test]
fn parallel_dispatch_is_bit_identical_to_sequential() {
    let n = 32;
    let run = |parallel: bool| -> (Vec<LaunchReport>, u64) {
        let mut rng = Rng::new(0xD17E);
        let mut array = Gpu::builder().config(cfg()).build_array(4).unwrap();
        array.set_parallel(parallel);
        let streams = [array.stream(), array.stream(), array.stream()];
        // Interleave three streams: reductions on 1 and 2, a transpose +
        // chained transpose (resident data, no input DMA) on 0.
        let mat: Vec<u32> = (0..(n * n) as u32).collect();
        array
            .launch_on(&streams[0], transpose::transpose(n))
            .input_words(0, mat)
            .submit();
        for round in 0..2 {
            for s in [&streams[1], &streams[2]] {
                let data: Vec<f32> = (0..n).map(|_| rng.f32_in(-2.0, 2.0)).collect();
                array
                    .launch_on(s, reduction::reduction(n))
                    .input_f32(0, &data)
                    .output(n, 1)
                    .submit();
            }
            if round == 0 {
                array
                    .launch_on(&streams[0], transpose::transpose(n))
                    .output(n * n, n * n)
                    .chained()
                    .submit();
            }
        }
        // Plus an unordered launch exercising earliest-free placement.
        let data: Vec<f32> = (0..n).map(|_| rng.f32_in(-2.0, 2.0)).collect();
        array
            .launch(reduction::reduction(n))
            .input_f32(0, &data)
            .output(n, 1)
            .submit();
        let rs = array.sync().unwrap();
        (rs, array.makespan())
    };

    let (seq, seq_span) = run(false);
    let (par, par_span) = run(true);
    assert_eq!(seq_span, par_span, "makespan must not depend on dispatch mode");
    assert_eq!(seq.len(), par.len());
    for (i, (a, b)) in seq.iter().zip(&par).enumerate() {
        assert_eq!(a.name, b.name, "job {i}: result order");
        assert_eq!(a.core, b.core, "job {i} ({}): core placement", a.name);
        assert_eq!(a.stream, b.stream, "job {i}");
        assert_eq!(a.compute_cycles, b.compute_cycles, "job {i} ({})", a.name);
        assert_eq!(a.bus_cycles, b.bus_cycles, "job {i} ({})", a.name);
        assert_eq!(
            (a.start, a.end),
            (b.start, b.end),
            "job {i} ({}): bus/compute timeline",
            a.name
        );
        assert_eq!(a.outputs, b.outputs, "job {i} ({})", a.name);
        assert_eq!(a.stats, b.stats, "job {i} ({}): full run stats", a.name);
    }

    // And against a single-core array (pure FIFO): the five reduction
    // jobs produce the same outputs and per-job compute cycles — only
    // the multi-core timeline overlap differs. (The chained transpose
    // pair needs its stream's data resident, so it only exists in the
    // multi-core mix.)
    let mut rng = Rng::new(0xD17E);
    let mut one = Gpu::builder().config(cfg()).build_array(1).unwrap();
    let s = one.stream();
    for _ in 0..5 {
        let data: Vec<f32> = (0..n).map(|_| rng.f32_in(-2.0, 2.0)).collect();
        one.launch_on(&s, reduction::reduction(n))
            .input_f32(0, &data)
            .output(n, 1)
            .submit();
    }
    let rs1 = one.sync().unwrap();
    let par_reductions: Vec<&LaunchReport> = par
        .iter()
        .filter(|r| r.name.starts_with("reduction"))
        .collect();
    assert_eq!(rs1.len(), par_reductions.len());
    for (a, b) in rs1.iter().zip(&par_reductions) {
        assert_eq!(a.compute_cycles, b.compute_cycles, "{}", a.name);
        assert_eq!(a.outputs, b.outputs, "{}", a.name);
    }
}

#[test]
fn mixed_stream_and_unordered_launches() {
    // Unordered launches fill free cores around a pinned stream.
    let n = 32;
    let mut rng = Rng::new(0x52);
    let mut array = Gpu::builder().config(cfg()).build_array(3).unwrap();
    let s = array.stream();
    let mut wants = Vec::new();
    for i in 0..6 {
        let data: Vec<f32> = (0..n).map(|_| rng.f32_in(-1.0, 1.0)).collect();
        wants.push(data.iter().sum::<f32>());
        let launch = if i % 2 == 0 {
            array.launch_on(&s, reduction::reduction(n))
        } else {
            array.launch(reduction::reduction(n))
        };
        launch.input_f32(0, &data).output(n, 1).submit();
    }
    let rs = array.sync().unwrap();
    let stream_cores: Vec<usize> =
        rs.iter().filter(|r| r.stream.is_some()).map(|r| r.core).collect();
    assert!(stream_cores.windows(2).all(|w| w[0] == w[1]), "stream hopped cores");
    for (r, want) in rs.iter().zip(&wants) {
        let got = r.output_f32(0)[0];
        assert!((got - want).abs() < want.abs() * 1e-4 + 1e-2, "{}", r.name);
    }
}
