//! The `egpu::obs` contract (ISSUE 10 acceptance):
//!
//! - traces are stamped in modeled bus cycles with a deterministic
//!   sequence key: sequential and parallel serving export
//!   byte-identical Chrome trace files, and two identical fresh runs
//!   reproduce the same bytes;
//! - recording is an observer, never a participant — turning it on
//!   leaves the `ServeReport` (every modeled number, histograms
//!   included) and the `SynthResult` bit-identical;
//! - span accounting closes: every served request carries the full
//!   admitted → batched → dispatched → exec → retired lifecycle
//!   exactly once, every shed request sheds exactly once, and the
//!   shed-reason counters in the metrics registry add up to the
//!   report's shed breakdown.

use std::collections::HashMap;

use egpu::api::{synthesize, AreaBudget, Server, SynthOptions};
use egpu::harness::loadgen::{demo_requests, heavy_tail_requests, BurstSpec, LoadSpec};
use egpu::obs::EventKind;
use egpu::serve::{Request, ShedReason};

/// The reference serving workload: enough traffic for several batch
/// windows on the demo fleet, deadlines on half the requests.
fn trace(seed: u64, requests: usize) -> Vec<Request> {
    demo_requests(&LoadSpec {
        seed,
        requests,
        mean_gap: 1_500,
        dim: 64,
        deadline_slack: Some(80_000),
    })
}

// ---------------------------------------------------------------
// Byte-identical export across dispatch modes and across reruns.
// ---------------------------------------------------------------

#[test]
fn sequential_and_parallel_traces_are_byte_identical() {
    let run = |sequential: bool| {
        let mut server = Server::builder()
            .sequential(sequential)
            .recording(true)
            .build()
            .unwrap();
        let report = server.serve(trace(0x0B5, 30)).unwrap();
        assert!(report.telemetry.completed > 0);
        let rec = server.recorder().expect("recording server has a recorder");
        (report, rec.chrome_trace(), rec.occupancy_report(server.num_cores()))
    };
    let (seq_report, seq_trace, seq_occ) = run(true);
    let (par_report, par_trace, par_occ) = run(false);
    assert_eq!(seq_report, par_report);
    // The exported artifacts carry no wall clock, no thread ids, no
    // dispatch-mode residue: bytes, not just semantics, must match.
    assert_eq!(seq_trace, par_trace, "trace bytes differ across dispatch modes");
    assert_eq!(seq_occ, par_occ, "occupancy report differs across dispatch modes");
    // And the trace is a real artifact, not an empty envelope.
    assert!(seq_trace.contains("\"traceEvents\""));
    assert!(seq_trace.contains("exec_start"));
}

#[test]
fn trace_export_is_reproducible_across_runs() {
    let run = || {
        let mut server = Server::builder().recording(true).build().unwrap();
        server.serve(trace(0x1DE0, 25)).unwrap();
        server.recorder().unwrap().chrome_trace()
    };
    assert_eq!(run(), run());
}

// ---------------------------------------------------------------
// Recording is free of modeled side effects.
// ---------------------------------------------------------------

#[test]
fn recording_leaves_the_serve_report_bit_identical() {
    let run = |recording: bool| {
        let mut server = Server::builder().recording(recording).build().unwrap();
        let report = server.serve(trace(0xFADE, 30)).unwrap();
        let util = server.core_utilization();
        let snap = server.stats_snapshot();
        (report, util, snap)
    };
    let (off_report, off_util, off_snap) = run(false);
    let (on_report, on_util, on_snap) = run(true);
    // Every modeled observable — results, shed records, telemetry
    // histograms, utilization, runtime counters — is untouched by the
    // recorder. Tracing observes the model; it never participates.
    assert_eq!(off_report, on_report);
    assert_eq!(off_util, on_util);
    assert_eq!(off_snap, on_snap);
    assert!(on_report.telemetry.completed > 0);
}

#[test]
fn recording_leaves_the_synth_result_bit_identical() {
    let budget = AreaBudget::demo();
    let trace = heavy_tail_requests(&BurstSpec::demo(8));
    let run = |recording: bool, jobs: usize| {
        let opts = SynthOptions {
            beam: 1,
            max_cores: 2,
            jobs,
            recording,
            ..SynthOptions::default()
        };
        synthesize(&budget, &trace, &opts).expect("demo budget must synthesize")
    };
    let base = run(false, 1);
    // Recording on, and recording on under parallel frontier scoring,
    // must reproduce the exact winner, score, audit trail and replay
    // count — the recorder is invisible to the search.
    assert_eq!(base, run(true, 1));
    assert_eq!(base, run(true, 2));
}

// ---------------------------------------------------------------
// Span accounting: the trace closes over the report.
// ---------------------------------------------------------------

#[test]
fn every_request_retires_or_sheds_exactly_once_in_the_trace() {
    // A saturating burst on a tight queue: real shedding alongside
    // real service, so both lifecycle endings appear in one trace.
    let offered = 60usize;
    let mut server = Server::builder()
        .qdepth(12)
        .max_batch(6)
        .recording(true)
        .build()
        .unwrap();
    let reqs = demo_requests(&LoadSpec {
        seed: 0x5A7,
        requests: offered,
        mean_gap: 0,
        dim: 64,
        deadline_slack: None,
    });
    let report = server.serve(reqs).unwrap();
    assert!(!report.shed.is_empty(), "this load must shed");
    assert!(!report.results.is_empty(), "this load must also serve");

    let events = server.recorder().unwrap().events();
    let mut admitted: HashMap<usize, u32> = HashMap::new();
    let mut retired: HashMap<usize, u32> = HashMap::new();
    let mut shed: HashMap<usize, u32> = HashMap::new();
    let mut execs: HashMap<usize, (u32, u32)> = HashMap::new();
    for e in &events {
        match &e.kind {
            EventKind::Admitted { req } => *admitted.entry(*req).or_default() += 1,
            EventKind::Retired { req, .. } => *retired.entry(*req).or_default() += 1,
            EventKind::Shed { req, .. } => *shed.entry(*req).or_default() += 1,
            EventKind::ExecStart { req, .. } => execs.entry(*req).or_default().0 += 1,
            EventKind::ExecEnd { req, .. } => execs.entry(*req).or_default().1 += 1,
            _ => {}
        }
    }
    // Served and shed partition the offered workload in the trace
    // exactly as in the report.
    for r in &report.results {
        assert_eq!(admitted.get(&r.id), Some(&1), "request {} admission", r.id);
        assert_eq!(retired.get(&r.id), Some(&1), "request {} retirement", r.id);
        assert_eq!(execs.get(&r.id), Some(&(1, 1)), "request {} exec span", r.id);
        assert!(!shed.contains_key(&r.id), "request {} both served and shed", r.id);
    }
    for s in &report.shed {
        assert_eq!(shed.get(&s.id), Some(&1), "request {} shed count", s.id);
        assert!(!retired.contains_key(&s.id), "request {} both shed and served", s.id);
    }
    assert_eq!(retired.len(), report.results.len());
    assert_eq!(shed.len(), report.shed.len());
    assert_eq!(retired.len() + shed.len(), offered, "no request may vanish");

    // Events are stamped in modeled time and exported in one total
    // order: (cycle, seq) is non-decreasing along the event stream.
    for w in events.windows(2) {
        assert!(
            (w[0].cycle, w[0].seq) <= (w[1].cycle, w[1].seq),
            "export order violates (cycle, seq)"
        );
    }

    // Satellite: the registry's shed-reason breakdown reconciles with
    // the report's own shed records.
    let metrics = server.metrics();
    let by_reason = |reason: ShedReason| {
        report.shed.iter().filter(|s| s.reason == reason).count() as u64
    };
    assert_eq!(
        metrics.counter("serve.shed.queue_full"),
        by_reason(ShedReason::QueueFull)
    );
    assert_eq!(
        metrics.counter("serve.shed.deadline_expired"),
        by_reason(ShedReason::DeadlineExpired)
    );
    assert_eq!(
        metrics.counter("serve.shed.queue_full")
            + metrics.counter("serve.shed.deadline_expired"),
        report.telemetry.shed
    );
}

#[test]
fn exec_spans_carry_the_modeled_timeline() {
    let mut server = Server::builder().recording(true).build().unwrap();
    let report = server.serve(trace(0xE2E, 20)).unwrap();
    let events = server.recorder().unwrap().events();
    // Each served result's span events are stamped with the report's
    // own modeled cycles: ExecStart at r.start, ExecEnd and Retired at
    // r.end, on the core the report names.
    for r in &report.results {
        let start = events.iter().any(|e| {
            matches!(&e.kind, EventKind::ExecStart { req, core, .. }
                if *req == r.id && *core == r.core)
                && e.cycle == r.start
        });
        let end = events.iter().any(|e| {
            matches!(&e.kind, EventKind::ExecEnd { req, cycles, .. }
                if *req == r.id && *cycles == r.compute_cycles)
                && e.cycle == r.end
        });
        assert!(start, "request {} has no ExecStart at cycle {}", r.id, r.start);
        assert!(end, "request {} has no ExecEnd at cycle {}", r.id, r.end);
    }
    // The disabled path records nothing at all.
    let mut off = Server::builder().build().unwrap();
    off.serve(trace(0xE2E, 20)).unwrap();
    assert!(off.recorder().is_none());
}
