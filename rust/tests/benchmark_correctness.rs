//! Cross-module correctness: every generated benchmark kernel, on every
//! memory mode, against its oracle — plus one kernel driven end-to-end
//! through the XLA datapath to prove the benchmark programs themselves
//! (not just single ops) are backend-independent.

use egpu::datapath::xla::XlaDatapath;
use egpu::harness::Rng;
use egpu::kernels::{bitonic, f32_bits, fft, mmm, reduction, transpose};
use egpu::runtime::default_artifacts_dir;
use egpu::sim::{EgpuConfig, Machine, MemoryMode};

#[test]
fn reduction_all_sizes_both_modes() {
    // 32/64/128 are the paper's dims; deeper trees need prefixes the
    // Table 3 depth selectors cannot express (documented in reduction.rs).
    let mut rng = Rng::new(1);
    for n in [32usize, 64, 128] {
        let data: Vec<f32> = (0..n).map(|_| rng.f32_in(-8.0, 8.0)).collect();
        let want: f32 = data.iter().sum();
        for memory in [MemoryMode::Dp, MemoryMode::Qp] {
            let cfg = EgpuConfig::benchmark(memory, false);
            let (stats, m) = reduction::reduction(n)
                .run(&cfg, &[(0, f32_bits(&data))])
                .unwrap_or_else(|e| panic!("{n} {memory:?}: {e}"));
            let got = f32::from_bits(m.shared().read(n as u32).unwrap());
            assert!(
                (got - want).abs() < want.abs() * 1e-4 + 1e-2,
                "{n} {memory:?}: {got} vs {want}"
            );
            assert_eq!(stats.hazards, 0, "{n} {memory:?}");
        }
    }
}

#[test]
fn reduction_dot_matches_tree() {
    let mut rng = Rng::new(2);
    for n in [32usize, 64, 128] {
        let data: Vec<f32> = (0..n).map(|_| rng.f32_in(-1.0, 1.0)).collect();
        let cfg = EgpuConfig::benchmark(MemoryMode::Dp, true);
        let (_, mt) = reduction::reduction(n).run(&cfg, &[(0, f32_bits(&data))]).unwrap();
        let (_, md) = reduction::reduction_dot(n).run(&cfg, &[(0, f32_bits(&data))]).unwrap();
        let t = f32::from_bits(mt.shared().read(n as u32).unwrap());
        let d = f32::from_bits(md.shared().read(n as u32).unwrap());
        assert!((t - d).abs() < t.abs() * 1e-4 + 1e-3, "n={n}: tree {t} dot {d}");
    }
}

#[test]
fn transpose_is_an_involution() {
    // transpose(transpose(A)) == A, using the machine itself both times.
    let n = 64;
    let mut rng = Rng::new(3);
    let data: Vec<u32> = (0..n * n).map(|_| rng.next_u32()).collect();
    let cfg = EgpuConfig::benchmark(MemoryMode::Dp, false);
    let (_, m1) = transpose::transpose(n).run(&cfg, &[(0, data.clone())]).unwrap();
    let once = m1.shared().read_block(n * n, n * n).to_vec();
    let (_, m2) = transpose::transpose(n).run(&cfg, &[(0, once)]).unwrap();
    assert_eq!(m2.shared().read_block(n * n, n * n), &data[..]);
}

#[test]
fn mmm_identity_and_associativity_spot_checks() {
    let n = 32;
    let cfg = mmm::config(n, MemoryMode::Dp, false);
    // A * I == A.
    let mut rng = Rng::new(4);
    let a: Vec<f32> = (0..n * n).map(|_| rng.f32_in(-2.0, 2.0)).collect();
    let mut ident = vec![0f32; n * n];
    for i in 0..n {
        ident[i * n + i] = 1.0;
    }
    let (_, m) = mmm::mmm(n)
        .run(&cfg, &[(0, f32_bits(&a)), (n * n, f32_bits(&ident))])
        .unwrap();
    for (i, want) in a.iter().enumerate() {
        let got = f32::from_bits(m.shared().read((2 * n * n + i) as u32).unwrap());
        assert!((got - want).abs() < 1e-4, "A*I [{i}]: {got} vs {want}");
    }
}

#[test]
fn bitonic_sorts_duplicates_and_extremes() {
    let n = 128;
    let cfg = EgpuConfig::benchmark_predicated(MemoryMode::Dp);
    let mut rng = Rng::new(5);
    let mut data: Vec<u32> = (0..n).map(|_| rng.below(4) as u32 * 1000).collect();
    data[0] = u32::MAX;
    data[n - 1] = 0;
    data[7] = u32::MAX;
    let (_, m) = bitonic::bitonic(n).run(&cfg, &[(0, data.clone())]).unwrap();
    assert_eq!(m.shared().read_block(0, n), &bitonic::oracle(&data)[..]);
}

#[test]
fn fft_linearity() {
    // FFT(a + b) == FFT(a) + FFT(b), each computed on the machine.
    let n = 64;
    let cfg = EgpuConfig::benchmark(MemoryMode::Dp, false);
    let mut rng = Rng::new(6);
    let a: Vec<f32> = (0..n).map(|_| rng.f32_in(-1.0, 1.0)).collect();
    let b: Vec<f32> = (0..n).map(|_| rng.f32_in(-1.0, 1.0)).collect();
    let sum: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
    let zeros = vec![0f32; n];
    let run = |re: &[f32]| -> Vec<f32> {
        let (_, m) = fft::fft(n).run(&cfg, &fft::shared_init(re, &zeros)).unwrap();
        (0..2 * n)
            .map(|i| f32::from_bits(m.shared().read(i as u32).unwrap()))
            .collect()
    };
    let fa = run(&a);
    let fb = run(&b);
    let fsum = run(&sum);
    for i in 0..2 * n {
        assert!(
            (fsum[i] - (fa[i] + fb[i])).abs() < 1e-2,
            "linearity at {i}: {} vs {}",
            fsum[i],
            fa[i] + fb[i]
        );
    }
}

#[test]
fn fft_impulse_is_flat() {
    // FFT of a unit impulse = all-ones spectrum.
    let n = 32;
    let cfg = EgpuConfig::benchmark(MemoryMode::Dp, false);
    let mut re = vec![0f32; n];
    re[0] = 1.0;
    let im = vec![0f32; n];
    let (_, m) = fft::fft(n).run(&cfg, &fft::shared_init(&re, &im)).unwrap();
    for k in 0..n {
        let gr = f32::from_bits(m.shared().read(k as u32).unwrap());
        let gi = f32::from_bits(m.shared().read((n + k) as u32).unwrap());
        assert!((gr - 1.0).abs() < 1e-4 && gi.abs() < 1e-4, "bin {k}: ({gr},{gi})");
    }
}

#[test]
fn full_benchmark_program_identical_on_xla_backend() {
    // The equivalence test (datapath_equivalence.rs) covers single ops;
    // this runs a whole generated benchmark through PJRT.
    if !default_artifacts_dir().join("opmap.json").is_file() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let n = 64;
    let mut rng = Rng::new(7);
    let data: Vec<f32> = (0..n).map(|_| rng.f32_in(0.5, 2.0)).collect();
    let cfg = EgpuConfig::benchmark(MemoryMode::Dp, true);
    let kernel = reduction::reduction(n);
    let prog = kernel.assemble(&cfg).unwrap();

    let mut native = Machine::new(cfg.clone()).unwrap();
    let be = XlaDatapath::new(default_artifacts_dir(), cfg.wavefronts()).unwrap();
    let mut xla = Machine::with_backend(cfg.clone(), Some(Box::new(be))).unwrap();
    for m in [&mut native, &mut xla] {
        m.load_program(prog.clone()).unwrap();
        m.set_threads(kernel.threads).unwrap();
        m.set_dim_x(kernel.dim_x).unwrap();
        m.shared_mut().write_block(0, &f32_bits(&data));
        m.run(1_000_000).unwrap();
    }
    assert_eq!(native.cycles(), xla.cycles());
    // The reduction tree is pure fadd over identical operands in identical
    // order → bit-exact between backends.
    assert_eq!(
        native.shared().read(n as u32).unwrap(),
        xla.shared().read(n as u32).unwrap(),
        "reduction result diverges between datapaths"
    );
}

#[test]
fn kernels_report_honest_thread_counts() {
    // Kernel.threads must be runnable on the benchmark configurations.
    for k in [
        reduction::reduction(128),
        transpose::transpose(64),
        mmm::mmm(64),
        bitonic::bitonic(256),
        fft::fft(256),
    ] {
        assert!(k.threads >= 16 && k.threads % 16 == 0 && k.threads <= 512, "{}", k.name);
        assert!(!k.asm.is_empty());
    }
}
