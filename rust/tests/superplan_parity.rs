//! Three-way execution-mode parity on the demo kernel suite.
//!
//! Every demo kernel (reduction, transpose, mmm, bitonic, fft, fft4)
//! runs through all three executors —
//!
//! 1. the fused superplan path (`run` with superplans on, the default),
//! 2. the per-instruction plan path (`run` after `set_superplans(false)`),
//! 3. the decode-per-issue reference (`run_reference`),
//!
//! — on identical inputs, and the results must be bit-for-bit equal:
//! `RunStats` (modeled cycles, retired instructions, hazard totals, and
//! the full per-`Group` `Profile`), every architectural register, and
//! all of shared memory. This is the contract that lets the superplan
//! compiler fuse basic blocks aggressively: it may change wall-clock
//! speed, never observable behavior.

use egpu::kernels::{bitonic, f32_bits, fft, fft4, mmm, reduction, transpose, Kernel};
use egpu::sim::{EgpuConfig, Machine, MemoryMode, Profile, RunStats};

/// Deterministic pseudo-random inputs (no external RNG dependency; the
/// constants are from the classic LCG in Numerical Recipes).
struct Lcg(u32);

impl Lcg {
    fn next_u32(&mut self) -> u32 {
        self.0 = self.0.wrapping_mul(1664525).wrapping_add(1013904223);
        self.0
    }

    fn f32_unit(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 / (1u32 << 24) as f32 * 2.0 - 1.0
    }
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Mode {
    Fused,
    Plan,
    Reference,
}

/// Run `kernel` under `mode` on a fresh machine with `init` preloaded
/// into shared memory; return stats + full architectural state.
fn run_mode(
    kernel: &Kernel,
    cfg: &EgpuConfig,
    init: &[(usize, Vec<u32>)],
    mode: Mode,
) -> (RunStats, Vec<u32>, Vec<u32>) {
    let mut m = Machine::new(cfg.clone()).unwrap();
    let prog = kernel.assemble(cfg).unwrap();
    m.load_program(prog).unwrap();
    m.set_threads(kernel.threads).unwrap();
    m.set_dim_x(kernel.dim_x).unwrap();
    for (base, data) in init {
        m.shared_mut().write_block(*base, data);
    }
    let stats = match mode {
        Mode::Fused => m.run(u64::MAX).unwrap(),
        Mode::Plan => {
            m.set_superplans(false);
            m.run(u64::MAX).unwrap()
        }
        Mode::Reference => m.run_reference(u64::MAX).unwrap(),
    };
    let regs: Vec<u32> = (0..kernel.threads)
        .flat_map(|t| (0..16u8).map(move |r| (t, r)))
        .map(|(t, r)| m.regs().read_thread(t, r))
        .collect();
    let mem = m.shared().read_block(0, cfg.shared_words()).to_vec();
    (stats, regs, mem)
}

/// The demo suite with per-kernel configs and inputs, sized to keep the
/// three-way sweep fast while still exercising loops, subroutines,
/// predication, and both shared-memory port models the kernels use.
fn demo_cases() -> Vec<(Kernel, EgpuConfig, Vec<(usize, Vec<u32>)>)> {
    let mut rng = Lcg(0x5EED_7A11);
    let base = EgpuConfig::benchmark(MemoryMode::Dp, false);
    let pred = EgpuConfig::benchmark_predicated(MemoryMode::Dp);

    let n = 128usize;
    let vecd = f32_bits(&(0..n).map(|_| rng.f32_unit()).collect::<Vec<_>>());
    let mat: Vec<u32> = (0..n * n).map(|_| rng.next_u32()).collect();
    let m = 64usize;
    let a = f32_bits(&(0..m * m).map(|_| rng.f32_unit()).collect::<Vec<_>>());
    let b = f32_bits(&(0..m * m).map(|_| rng.f32_unit()).collect::<Vec<_>>());
    let sortd: Vec<u32> = (0..256).map(|_| rng.next_u32()).collect();
    let re: Vec<f32> = (0..256).map(|_| rng.f32_unit()).collect();
    let im = vec![0f32; 256];

    vec![
        (reduction::reduction(n), base.clone(), vec![(0, vecd)]),
        (transpose::transpose(n), base.clone(), vec![(0, mat)]),
        (
            mmm::mmm(m),
            mmm::config(m, MemoryMode::Dp, false),
            vec![(0, a), (m * m, b)],
        ),
        (bitonic::bitonic(256), pred, vec![(0, sortd)]),
        (fft::fft(256), base.clone(), fft::shared_init(&re, &im)),
        (fft4::fft4(256), base, fft4::shared_init(&re, &im)),
    ]
}

#[test]
fn demo_kernels_bit_identical_across_all_three_executors() {
    for (kernel, cfg, init) in demo_cases() {
        let fused = run_mode(&kernel, &cfg, &init, Mode::Fused);
        for mode in [Mode::Plan, Mode::Reference] {
            let other = run_mode(&kernel, &cfg, &init, mode);
            // Profile first: a per-`Group` count or cycle drift under
            // fusion is the most likely regression and deserves its own
            // readable failure.
            assert_profiles_equal(&kernel.name, mode, &fused.0.profile, &other.0.profile);
            assert_eq!(
                fused.0, other.0,
                "{}: RunStats diverge between fused and {:?}",
                kernel.name, mode
            );
            assert_eq!(
                fused.1, other.1,
                "{}: registers diverge between fused and {:?}",
                kernel.name, mode
            );
            assert_eq!(
                fused.2, other.2,
                "{}: shared memory diverges between fused and {:?}",
                kernel.name, mode
            );
        }
    }
}

fn assert_profiles_equal(kernel: &str, mode: Mode, fused: &Profile, other: &Profile) {
    assert_eq!(
        fused, other,
        "{kernel}: per-group profile diverges between fused and {mode:?}\n\
         fused:\n{}\nother:\n{}",
        fused.render(),
        other.render()
    );
}

#[test]
fn demo_kernels_actually_fuse() {
    // Guard against the parity test passing vacuously because the
    // superplan compiler stopped producing traces: every demo kernel
    // must retire a nonzero share of its dynamic instructions fused.
    for (kernel, cfg, init) in demo_cases() {
        let mut m = Machine::new(cfg.clone()).unwrap();
        m.load_program(kernel.assemble(&cfg).unwrap()).unwrap();
        m.set_threads(kernel.threads).unwrap();
        m.set_dim_x(kernel.dim_x).unwrap();
        for (base, data) in &init {
            m.shared_mut().write_block(*base, data);
        }
        m.run(u64::MAX).unwrap();
        let ts = m.trace_stats();
        assert!(
            ts.traces > 0 && ts.fused_retired > 0,
            "{}: no fused traces executed ({:?})",
            kernel.name,
            ts
        );
    }
}
