//! Kernel-compiler correctness pinning, the way PR 2 pinned the
//! issue-plan engine:
//!
//! - For every benchmark kernel, the list-scheduled build and the
//!   fully-fenced (schedule-disabled) build produce bit-identical
//!   registers and shared memory through `Machine::run`, report zero
//!   hazards, and the scheduled build never exceeds the fenced cycle
//!   count.
//! - The pretty-printed listing reassembles to exactly the lowered
//!   program (no string round-trip is needed, but the text form must not
//!   drift from the binary form).
//! - A randomized-DAG property sweep (predicated and narrowed `[wN,dN]`
//!   instructions included) holds the same invariants on generated
//!   programs.
//! - At shallow configurations, list scheduling beats in-order padding on
//!   modeled cycles for several kernels (the delay slots get filled).

use egpu::asm::assemble;
use egpu::harness::Rng;
use egpu::isa::{CondCode, DepthSel, TType, ThreadCtrl, WidthSel, WordLayout};
use egpu::kc::{KernelBuilder, SchedMode};
use egpu::kernels::{bitonic, f32_bits, fft, fft4, mmm, reduction, transpose, Kernel};
use egpu::sim::{EgpuConfig, Machine, MemoryMode};

/// Full architectural state: every register of every thread, all of
/// shared memory.
fn state(m: &Machine) -> (Vec<u32>, Vec<u32>) {
    let threads = m.regs().threads();
    let rpt = m.regs().regs_per_thread();
    let regs = (0..threads)
        .flat_map(|t| (0..rpt as u8).map(move |r| (t, r)))
        .map(|(t, r)| m.regs().read_thread(t, r))
        .collect();
    let mem = m.shared().read_block(0, m.shared().len()).to_vec();
    (regs, mem)
}

/// Run one kernel build and return (stats, full state).
fn run(k: &Kernel, cfg: &EgpuConfig, init: &[(usize, Vec<u32>)]) -> (u64, (Vec<u32>, Vec<u32>)) {
    let (stats, m) = k.run(cfg, init).unwrap_or_else(|e| panic!("{}: {e}", k.name));
    assert_eq!(
        stats.hazards, 0,
        "{}: hazards {:?}\n{}",
        k.name, stats.hazard_samples, k.asm
    );
    (stats.cycles, state(&m))
}

/// The tentpole invariant for one kernel: scheduled ≡ fenced bit-for-bit,
/// scheduled cycles ≤ fenced cycles; the listing reassembles to the
/// lowered program.
fn assert_schedule_identity(
    build: impl Fn(SchedMode) -> Kernel,
    cfg: &EgpuConfig,
    init: &[(usize, Vec<u32>)],
) {
    let list = build(SchedMode::List);
    let fenced = build(SchedMode::Fenced);
    let (cy_list, st_list) = run(&list, cfg, init);
    let (cy_fen, st_fen) = run(&fenced, cfg, init);
    assert!(
        cy_list <= cy_fen,
        "{}: scheduled {cy_list} cycles > fenced {cy_fen}",
        list.name
    );
    assert_eq!(st_list.0, st_fen.0, "{}: register files diverge", list.name);
    assert_eq!(st_list.1, st_fen.1, "{}: shared memory diverges", list.name);

    let prog = list.program.as_ref().expect("compiled kernel carries its program");
    let re = assemble(&list.asm, prog.layout).unwrap_or_else(|e| panic!("{}: {e}", list.name));
    assert_eq!(prog.instrs, re.instrs, "{}: listing drifts from program", list.name);
    assert_eq!(prog.words, re.words, "{}: encodings drift", list.name);
}

#[test]
fn reduction_scheduled_matches_fenced() {
    let mut rng = Rng::new(0xE1);
    for n in [32usize, 128] {
        let d: Vec<f32> = (0..n).map(|_| rng.f32_in(-4.0, 4.0)).collect();
        let init = vec![(0usize, f32_bits(&d))];
        assert_schedule_identity(
            |m| reduction::reduction_mode(n, m),
            &EgpuConfig::benchmark(MemoryMode::Dp, false),
            &init,
        );
    }
}

#[test]
fn reduction_dot_scheduled_matches_fenced() {
    let mut rng = Rng::new(0xE2);
    let n = 64;
    let d: Vec<f32> = (0..n).map(|_| rng.f32_in(-1.0, 1.0)).collect();
    let init = vec![(0usize, f32_bits(&d))];
    assert_schedule_identity(
        |m| reduction::reduction_dot_mode(n, m),
        &EgpuConfig::benchmark(MemoryMode::Dp, true),
        &init,
    );
}

#[test]
fn reduction_predicated_scheduled_matches_fenced() {
    let mut rng = Rng::new(0xE3);
    let n = 64;
    let d: Vec<f32> = (0..n).map(|_| rng.f32_in(-2.0, 2.0)).collect();
    let init = vec![(0usize, f32_bits(&d))];
    assert_schedule_identity(
        |m| reduction::reduction_predicated_mode(n, m),
        &EgpuConfig::benchmark_predicated(MemoryMode::Dp),
        &init,
    );
}

#[test]
fn transpose_scheduled_matches_fenced() {
    let mut rng = Rng::new(0xE4);
    let n = 32;
    let d: Vec<u32> = (0..n * n).map(|_| rng.next_u32()).collect();
    let init = vec![(0usize, d)];
    for memory in [MemoryMode::Dp, MemoryMode::Qp] {
        assert_schedule_identity(
            |m| transpose::transpose_mode(n, memory, m),
            &EgpuConfig::benchmark(memory, false),
            &init,
        );
    }
}

#[test]
fn mmm_scheduled_matches_fenced() {
    let mut rng = Rng::new(0xE5);
    let n = 32;
    let a: Vec<f32> = (0..n * n).map(|_| rng.f32_in(-2.0, 2.0)).collect();
    let b: Vec<f32> = (0..n * n).map(|_| rng.f32_in(-2.0, 2.0)).collect();
    let init = vec![(0usize, f32_bits(&a)), (n * n, f32_bits(&b))];
    assert_schedule_identity(
        |m| mmm::mmm_mode(n, MemoryMode::Dp, m),
        &mmm::config(n, MemoryMode::Dp, false),
        &init,
    );
    assert_schedule_identity(
        |m| mmm::mmm_dot_mode(n, m),
        &mmm::config(n, MemoryMode::Dp, true),
        &init,
    );
}

#[test]
fn bitonic_scheduled_matches_fenced() {
    let mut rng = Rng::new(0xE6);
    let n = 64;
    let d: Vec<u32> = (0..n).map(|_| rng.next_u32() >> 2).collect();
    let init = vec![(0usize, d)];
    assert_schedule_identity(
        |m| bitonic::bitonic_mode(n, MemoryMode::Dp, m),
        &EgpuConfig::benchmark_predicated(MemoryMode::Dp),
        &init,
    );
}

#[test]
fn fft_scheduled_matches_fenced() {
    let mut rng = Rng::new(0xE7);
    let n = 64;
    let re: Vec<f32> = (0..n).map(|_| rng.f32_in(-1.0, 1.0)).collect();
    let im: Vec<f32> = (0..n).map(|_| rng.f32_in(-1.0, 1.0)).collect();
    let init = fft::shared_init(&re, &im);
    assert_schedule_identity(
        |m| fft::fft_mode(n, MemoryMode::Dp, m),
        &EgpuConfig::benchmark(MemoryMode::Dp, false),
        &init,
    );
}

#[test]
fn fft4_scheduled_matches_fenced() {
    let mut rng = Rng::new(0xE8);
    let n = 64;
    let re: Vec<f32> = (0..n).map(|_| rng.f32_in(-1.0, 1.0)).collect();
    let im: Vec<f32> = (0..n).map(|_| rng.f32_in(-1.0, 1.0)).collect();
    let init = fft4::shared_init(&re, &im);
    assert_schedule_identity(
        |m| fft4::fft4_mode(n, MemoryMode::Dp, m),
        &EgpuConfig::benchmark(MemoryMode::Dp, false),
        &init,
    );
}

#[test]
fn shallow_kernels_fill_delay_slots() {
    // Acceptance: at shallow configurations (16-64 threads) at least two
    // kernels show a measured modeled-cycle reduction of list scheduling
    // over in-order padding. (The same numbers land in
    // BENCH_simulator.json's "static_schedule" section.)
    fn f32v(rng: &mut Rng, n: usize) -> Vec<u32> {
        let v: Vec<f32> = (0..n).map(|_| rng.f32_in(-1.0, 1.0)).collect();
        f32_bits(&v)
    }
    let mut rng = Rng::new(0xE9);
    let base = EgpuConfig::benchmark(MemoryMode::Dp, false);
    let pred = EgpuConfig::benchmark_predicated(MemoryMode::Dp);
    let v32 = f32v(&mut rng, 32);
    let m32: Vec<u32> = (0..32 * 32).map(|_| rng.next_u32()).collect();
    let a32 = f32v(&mut rng, 32 * 32);
    let b32 = f32v(&mut rng, 32 * 32);
    let s64: Vec<u32> = (0..64).map(|_| rng.next_u32()).collect();
    let re64: Vec<f32> = (0..64).map(|_| rng.f32_in(-1.0, 1.0)).collect();
    let im64 = vec![0f32; 64];

    type BuildFn = Box<dyn Fn(SchedMode) -> Kernel>;
    let cases: Vec<(BuildFn, EgpuConfig, Vec<(usize, Vec<u32>)>)> = vec![
        (
            Box::new(|m| reduction::reduction_mode(32, m)) as BuildFn,
            base.clone(),
            vec![(0, v32)],
        ),
        (
            Box::new(|m| transpose::transpose_mode(32, MemoryMode::Dp, m)),
            base.clone(),
            vec![(0, m32)],
        ),
        (
            Box::new(|m| mmm::mmm_mode(32, MemoryMode::Dp, m)),
            mmm::config(32, MemoryMode::Dp, false),
            vec![(0, a32), (32 * 32, b32)],
        ),
        (
            Box::new(|m| bitonic::bitonic_mode(64, MemoryMode::Dp, m)),
            pred,
            vec![(0, s64)],
        ),
        (
            Box::new(|m| fft::fft_mode(64, MemoryMode::Dp, m)),
            base.clone(),
            fft::shared_init(&re64, &im64),
        ),
        (
            Box::new(|m| fft4::fft4_mode(64, MemoryMode::Dp, m)),
            base,
            fft4::shared_init(&re64, &im64),
        ),
    ];
    let mut wins = 0usize;
    let mut report = String::new();
    for (build, cfg, init) in &cases {
        let list = build(SchedMode::List);
        let linear = build(SchedMode::Linear);
        let (cy_list, _) = run(&list, cfg, init);
        let (cy_lin, _) = run(&linear, cfg, init);
        assert!(
            cy_list <= cy_lin,
            "{}: list {cy_list} > linear {cy_lin}",
            list.name
        );
        report.push_str(&format!("{}: list {cy_list} vs padded {cy_lin}\n", list.name));
        if cy_list < cy_lin {
            wins += 1;
        }
    }
    assert!(
        wins >= 2,
        "expected >= 2 kernels with a modeled-cycle reduction, got {wins}:\n{report}"
    );
}

// ---------------------------------------------------------------------
// Randomized-DAG property sweep (in the style of asm_sim_properties.rs).
// ---------------------------------------------------------------------

fn random_tc(rng: &mut Rng) -> ThreadCtrl {
    let w = *rng.choose(&[WidthSel::All16, WidthSel::Quarter4, WidthSel::Sp0]);
    let d = *rng.choose(&[
        DepthSel::Wave0,
        DepthSel::All,
        DepthSel::Half,
        DepthSel::Quarter,
    ]);
    ThreadCtrl::new(w, d)
}

/// A generated value plus what it *deterministically* covers: the thread
/// rectangle its first definition wrote, and the predicate-region path it
/// was defined under. A read is only deterministic (identical across
/// schedule modes and register assignments) when every lane it touches
/// was written by this value — lanes outside the def's coverage hold
/// whatever previously occupied the physical register, which is an
/// allocation artifact, not program semantics.
#[derive(Clone)]
struct GenVal {
    v: egpu::kc::V,
    lanes: usize,
    waves: usize,
    /// Predicate-region path at definition (empty = unpredicated).
    path: Vec<u32>,
}

/// Random straight-line-with-predicates program built through the
/// compiler IR: ALU chains, loads/stores, `_into` redefinitions,
/// IF/ELSE/ENDIF regions, random `[wN,dN]` narrowing. The same seed
/// yields the same program in every mode. Operand choice respects
/// coverage (see [`GenVal`]) so results are well-defined — which is also
/// the discipline the real kernels follow.
fn random_kernel(seed: u64, threads: usize, len: usize, mode: SchedMode) -> Kernel {
    let total_waves = threads / 16;
    let mut rng = Rng::new(seed);
    let mut b = KernelBuilder::new("prop", threads, WordLayout::for_regs(32), MemoryMode::Dp);
    let t = b.tdx();
    let t_val = GenVal {
        v: t,
        lanes: 16,
        waves: total_waves,
        path: Vec::new(),
    };
    // Operands come from a small rolling window so register pressure
    // stays bounded no matter the program length.
    let mut recent: Vec<GenVal> = vec![t_val.clone()];
    let mut path: Vec<u32> = Vec::new();
    let mut next_region = 0u32;
    let pick = |rng: &mut Rng,
                recent: &[GenVal],
                t_val: &GenVal,
                lanes: usize,
                waves: usize,
                path: &[u32]| {
        let window = &recent[recent.len().saturating_sub(8)..];
        let cands: Vec<&GenVal> = window
            .iter()
            .filter(|g| g.lanes >= lanes && g.waves >= waves && path.starts_with(&g.path))
            .collect();
        if cands.is_empty() {
            t_val.clone()
        } else {
            (*rng.choose(&cands)).clone()
        }
    };
    let mut depth = 0usize;
    for _ in 0..len {
        let tc = random_tc(&mut rng);
        let (lanes, waves) = (tc.width.lanes(), tc.depth.waves(total_waves));
        b.space(tc);
        let push = |recent: &mut Vec<GenVal>, v: egpu::kc::V, path: &[u32]| {
            recent.push(GenVal {
                v,
                lanes,
                waves,
                path: path.to_vec(),
            });
        };
        match rng.below(14) {
            0 => {
                let a = pick(&mut rng, &recent, &t_val, lanes, waves, &path);
                let c = pick(&mut rng, &recent, &t_val, lanes, waves, &path);
                let v = b.add_u(a.v, c.v);
                push(&mut recent, v, &path);
            }
            1 => {
                let a = pick(&mut rng, &recent, &t_val, lanes, waves, &path);
                let c = pick(&mut rng, &recent, &t_val, lanes, waves, &path);
                let v = b.op2(egpu::isa::Opcode::Sub, TType::Uint, a.v, c.v);
                push(&mut recent, v, &path);
            }
            2 => {
                let a = pick(&mut rng, &recent, &t_val, lanes, waves, &path);
                let c = pick(&mut rng, &recent, &t_val, lanes, waves, &path);
                let v = b.xor_i(a.v, c.v);
                push(&mut recent, v, &path);
            }
            3 => {
                let a = pick(&mut rng, &recent, &t_val, lanes, waves, &path);
                let c = pick(&mut rng, &recent, &t_val, lanes, waves, &path);
                let v = b.fadd(a.v, c.v);
                push(&mut recent, v, &path);
            }
            4 => {
                let a = pick(&mut rng, &recent, &t_val, lanes, waves, &path);
                let c = pick(&mut rng, &recent, &t_val, lanes, waves, &path);
                let v = b.fmul(a.v, c.v);
                push(&mut recent, v, &path);
            }
            5 => {
                let v = b.ldi(rng.range_i64(-200, 200));
                push(&mut recent, v, &path);
            }
            6 => {
                // Partial redefinition of a live value (WAW/WAR edges).
                // The target keeps its recorded coverage: lanes the new
                // def misses retain the value's own older data, which is
                // still deterministic. Never redefine `t` (the address
                // anchor).
                let d = pick(&mut rng, &recent, &t_val, 1, 1, &path);
                let a = pick(&mut rng, &recent, &t_val, lanes, waves, &path);
                let c = pick(&mut rng, &recent, &t_val, lanes, waves, &path);
                if d.v != t {
                    b.add_u_into(d.v, a.v, c.v);
                } else {
                    let v = b.add_u(a.v, c.v);
                    push(&mut recent, v, &path);
                }
            }
            7 | 8 => {
                let v = b.lod(t, rng.below(64) * 8);
                push(&mut recent, v, &path);
            }
            9 | 10 => {
                let v = pick(&mut rng, &recent, &t_val, lanes, waves, &path);
                b.sto(v.v, t, 2048 + rng.below(64) * 8);
            }
            11 if depth < 5 => {
                // Predicate ops run over the full thread space so pushes
                // and pops stay balanced for every thread.
                let a = pick(&mut rng, &recent, &t_val, 16, total_waves, &path);
                let c = pick(&mut rng, &recent, &t_val, 16, total_waves, &path);
                let cc = *rng.choose(&CondCode::ALL);
                b.full().if_cc(cc, TType::Uint, a.v, c.v);
                depth += 1;
                next_region += 1;
                path.push(next_region);
            }
            12 if depth > 0 => {
                b.full().else_();
                next_region += 1;
                *path.last_mut().unwrap() = next_region;
            }
            13 if depth > 0 => {
                b.full().endif();
                depth -= 1;
                path.pop();
            }
            _ => {
                let a = pick(&mut rng, &recent, &t_val, lanes, waves, &path);
                let v = b.op1(egpu::isa::Opcode::Neg, TType::Int, a.v);
                push(&mut recent, v, &path);
            }
        }
    }
    b.full();
    for _ in 0..depth {
        b.endif();
    }
    b.stop();
    Kernel::from_compiled("prop", b.finish(mode).unwrap(), threads, threads)
}

#[test]
fn random_dags_scheduled_match_fenced() {
    let mut rng = Rng::new(0x5C8D);
    let cfg = EgpuConfig::default(); // 512 threads, predicates configured
    for case in 0..60 {
        let seed = rng.next_u64();
        let threads = *rng.choose(&[16usize, 64, 256, 512]);
        let len = 10 + rng.below(35);
        let list = random_kernel(seed, threads, len, SchedMode::List);
        let linear = random_kernel(seed, threads, len, SchedMode::Linear);
        let fenced = random_kernel(seed, threads, len, SchedMode::Fenced);
        let (cy_list, st_list) = run(&list, &cfg, &[]);
        let (cy_lin, st_lin) = run(&linear, &cfg, &[]);
        let (cy_fen, st_fen) = run(&fenced, &cfg, &[]);
        assert!(
            cy_list <= cy_lin && cy_lin <= cy_fen,
            "case {case}: cycles not ordered: list {cy_list}, linear {cy_lin}, fenced {cy_fen}\n{}",
            list.asm
        );
        assert_eq!(st_list, st_lin, "case {case}: list vs linear state\n{}", list.asm);
        assert_eq!(st_list, st_fen, "case {case}: list vs fenced state\n{}", list.asm);
        // Listing round-trip on the scheduled build.
        let prog = list.program.as_ref().unwrap();
        let re = assemble(&list.asm, prog.layout).unwrap();
        assert_eq!(prog.instrs, re.instrs, "case {case}\n{}", list.asm);
    }
}
