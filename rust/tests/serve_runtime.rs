//! Integration tests for the serving runtime (`egpu::serve`).
//!
//! The acceptance contract of the serving layer:
//! (a) with a fixed seed, sequential and parallel serving produce
//!     bit-identical results and identical modeled-latency telemetry;
//! (b) a saturating arrival rate sheds requests instead of growing the
//!     queue without bound, and every shed request is reported;
//! (c) deadline/priority ordering is honored within a batch window;
//! (d) steady-state serving performs exactly one compile per
//!     `(spec, config fingerprint)` through the shared `KernelCache`;
//! (e) steady-state serving reuses each core's resident machine — a
//!     repeat round adds machine-reuse hits only, never reloads.

use egpu::api::{Gpu, KernelSpec, Server, ShedReason};
use egpu::harness::loadgen::{demo_requests, LoadSpec};
use egpu::kernels::f32_bits;
use egpu::serve::Request;

/// The reference serving workload for these tests: enough traffic to
/// form several batches on the demo fleet, with deadlines on half the
/// requests.
fn trace(seed: u64, requests: usize) -> Vec<Request> {
    demo_requests(&LoadSpec {
        seed,
        requests,
        mean_gap: 1_500,
        dim: 64,
        deadline_slack: Some(80_000),
    })
}

// ---------------------------------------------------------------
// (a) Determinism: sequential and parallel serving are bit-identical.
// ---------------------------------------------------------------

#[test]
fn sequential_and_parallel_serving_are_bit_identical() {
    let run = |sequential: bool| {
        let mut server = Server::builder().sequential(sequential).build().unwrap();
        let report = server.serve(trace(0xD15C0, 30)).unwrap();
        let util = server.core_utilization();
        (report, util)
    };
    let (seq, seq_util) = run(true);
    let (par, par_util) = run(false);
    // Results (outputs, cores, every timeline number), shed records
    // and the full telemetry (histograms included) must be equal —
    // ServeReport is integer-only, so this is bit-for-bit.
    assert_eq!(seq, par);
    assert_eq!(seq_util, par_util);
    // And the workload actually exercised the fleet.
    assert!(seq.telemetry.completed > 0);
    assert!(seq.telemetry.batches > 1, "want several batch windows");
    assert!(seq.results.iter().any(|r| !r.outputs.is_empty()));
}

#[test]
fn serving_is_reproducible_across_runs() {
    let run = || {
        let mut server = Server::builder().build().unwrap();
        server.serve(trace(0xABCD, 25)).unwrap()
    };
    assert_eq!(run(), run());
}

#[test]
fn borrowed_trace_serving_matches_owned() {
    // `serve_slice` (the synth scorer's zero-copy path) and `serve`
    // are one serve loop — identical ServeReport, bit-for-bit.
    let t = trace(0xB0B0, 20);
    let mut borrowed = Server::builder().build().unwrap();
    let mut owned = Server::builder().build().unwrap();
    let a = borrowed.serve_slice(&t).unwrap();
    let b = owned.serve(t).unwrap();
    assert_eq!(a, b);
    assert!(a.telemetry.completed > 0);
}

// ---------------------------------------------------------------
// (b) Saturation: bounded queue, load-shedding, full accounting.
// ---------------------------------------------------------------

#[test]
fn saturating_arrivals_shed_instead_of_growing_the_queue() {
    let offered = 200usize;
    let qdepth = 16usize;
    let mut server = Server::builder().qdepth(qdepth).max_batch(8).build().unwrap();
    // Everything arrives at cycle 0: far beyond the queue bound.
    let reqs = demo_requests(&LoadSpec {
        seed: 0xF00D,
        requests: offered,
        mean_gap: 0,
        dim: 64,
        deadline_slack: None,
    });
    let report = server.serve(reqs).unwrap();
    // Accounting identity: every offered request is served or shed.
    assert_eq!(report.submitted(), offered);
    assert_eq!(
        report.results.len() + report.shed.len(),
        offered,
        "no request may vanish"
    );
    // The queue never grew past its bound...
    assert!(
        report.telemetry.peak_queue <= qdepth,
        "peak {} exceeds bound {qdepth}",
        report.telemetry.peak_queue
    );
    // ...which forces real shedding at this load, each shed reported
    // with a reason and a shed time.
    assert!(!report.shed.is_empty());
    assert!(report.shed.iter().all(|s| s.reason == ShedReason::QueueFull));
    let served: Vec<usize> = report.results.iter().map(|r| r.id).collect();
    for s in &report.shed {
        assert!(!served.contains(&s.id), "request {} both served and shed", s.id);
    }
    assert_eq!(report.telemetry.shed, report.shed.len() as u64);
}

// ---------------------------------------------------------------
// (c) Deadline/priority ordering within the batch window.
// ---------------------------------------------------------------

#[test]
fn deadline_priority_order_is_honored_within_batch_windows() {
    let mut server = Server::builder().qdepth(64).max_batch(4).build().unwrap();
    // 12 requests all arrive at cycle 0 with shuffled deadlines,
    // priorities breaking ties among the deadline-free tail.
    let n = 64usize;
    let data: Vec<u32> = f32_bits(&(0..n).map(|i| i as f32 * 0.5).collect::<Vec<_>>());
    let deadlines = [
        Some(900_000u64),
        None,
        Some(300_000),
        Some(1_200_000),
        None,
        Some(600_000),
        Some(150_000),
        None,
        Some(450_000),
        Some(750_000),
        None,
        Some(1_050_000),
    ];
    let priorities = [0u8, 3, 0, 0, 1, 0, 0, 0, 0, 0, 2, 0];
    let reqs: Vec<Request> = deadlines
        .iter()
        .zip(priorities)
        .map(|(&d, p)| {
            let mut r = Request::new(KernelSpec::Reduction { n })
                .load(0, data.clone())
                .unload(n, 1)
                .priority(p);
            if let Some(d) = d {
                r = r.due_by(d);
            }
            r
        })
        .collect();
    let report = server.serve(reqs).unwrap();
    assert_eq!(report.results.len(), 12, "nothing sheds at these deadlines");
    // Dispatch order (across the three 4-request windows drawn from
    // one time-0 backlog) must follow the total order: oldest deadline
    // first, no-deadline last, priority breaking ties.
    let keys: Vec<(u64, u8, usize)> = report
        .results
        .iter()
        .map(|r| (r.deadline.unwrap_or(u64::MAX), u8::MAX - priorities[r.id], r.id))
        .collect();
    let mut sorted = keys.clone();
    sorted.sort();
    assert_eq!(keys, sorted, "dispatch order violates the deadline/priority key");
    // Batch indices are non-decreasing along dispatch order and
    // bounded by the batch size.
    assert!(report.results.windows(2).all(|w| w[0].batch <= w[1].batch));
    assert_eq!(report.telemetry.batches, 3);
    // The most urgent deadline landed in the first batch.
    let first = report.results.iter().find(|r| r.deadline == Some(150_000)).unwrap();
    assert_eq!(first.batch, 0);
}

#[test]
fn expired_deadlines_are_shed_and_reported() {
    // A deadline that expires before the fleet can even start the
    // request (the window must linger for the later arrivals first)
    // sheds with DeadlineExpired instead of wasting fleet time.
    let mut server = Server::builder().qdepth(8).max_batch(2).build().unwrap();
    let n = 64usize;
    let data: Vec<u32> = f32_bits(&(0..n).map(|i| i as f32).collect::<Vec<_>>());
    let mk = |arrival: u64| {
        Request::new(KernelSpec::Reduction { n })
            .load(0, data.clone())
            .unload(n, 1)
            .at(arrival)
    };
    let reqs = vec![
        mk(0),
        mk(0),
        mk(0),
        mk(0),
        // Arrives while the fleet drains the backlog; its deadline has
        // passed by the time a batch window could take it.
        mk(2_000).due_by(2_001),
    ];
    let report = server.serve(reqs).unwrap();
    assert_eq!(report.results.len(), 4);
    assert_eq!(report.shed.len(), 1);
    assert_eq!(report.shed[0].id, 4);
    assert_eq!(report.shed[0].reason, ShedReason::DeadlineExpired);
    assert!(report.shed[0].at >= 2_001);
}

// ---------------------------------------------------------------
// (d) Steady state: one compile per (spec, config fingerprint).
// ---------------------------------------------------------------

#[test]
fn steady_state_compiles_once_per_spec_and_fingerprint() {
    let mut server = Server::builder().build().unwrap();
    let first = server.serve(trace(0x11, 40)).unwrap();
    assert!(first.telemetry.completed > 0);
    let warm = server.cache_stats();
    assert!(warm.compiles > 0);
    // Every compile produced a distinct (spec, fingerprint) entry —
    // nothing was ever compiled twice.
    assert_eq!(warm.compiles, warm.entries as u64);
    // The demo fleet has two fingerprints (DP and QP at 32 regs) and
    // the trace five specs: the compile count is bounded by the grid.
    assert!(warm.compiles <= 10, "compiles {} exceed the spec grid", warm.compiles);

    // A second round of the same workload on a fresh measurement
    // window: identical initial state + identical trace = identical
    // placements, so it is served entirely from the cache — zero new
    // compiles, only hits.
    server.reset_timeline();
    let second = server.serve(trace(0x11, 40)).unwrap();
    assert!(second.telemetry.completed > 0);
    assert_eq!(second, first, "a warm replay is bit-identical to the cold round");
    let steady = server.cache_stats();
    assert_eq!(
        steady.compiles, warm.compiles,
        "steady-state serving must not recompile"
    );
    assert_eq!(steady.entries, warm.entries);
    assert!(steady.hits > warm.hits, "repeat launches must hit the cache");
}

#[test]
fn cache_stats_surface_on_gpu_and_array() {
    // Satellite: the compile-once property is assertable through the
    // api handles themselves, not just the fleet CLI.
    let mut gpu = Gpu::builder().build().unwrap();
    let spec = KernelSpec::Reduction { n: 64 };
    let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
    for _ in 0..3 {
        let buf = gpu.alloc_at::<f32>(0, 64).unwrap();
        gpu.upload(&buf, &data).unwrap();
        gpu.launch_spec(&spec).unwrap().run().unwrap();
    }
    let s = gpu.cache_stats();
    assert_eq!(s.compiles, 1, "one compile for three launches");
    assert_eq!(s.hits, 2);
    assert_eq!(s.entries, 1);

    let mut array = Gpu::builder().build_array(2).unwrap();
    for _ in 0..2 {
        let stream = array.stream();
        array
            .launch_spec(&stream, spec)
            .unwrap()
            .input_words(0, f32_bits(&data))
            .output(64, 1)
            .submit();
    }
    array.sync().unwrap();
    let s = array.cache_stats();
    assert_eq!(s.compiles, 1, "homogeneous array: one fingerprint, one compile");
    assert!(s.hits >= 1);
}

// ---------------------------------------------------------------
// (e) Machine reuse: steady state re-runs resident programs in place.
// ---------------------------------------------------------------

#[test]
fn steady_state_reuses_resident_machines() {
    // A single-spec workload: after each core's first job, every later
    // job placed on that core finds the program already resident and is
    // served by an in-place machine reset — no reassembly, no regfile
    // or shared-memory reallocation.
    let mut server = Server::builder().build().unwrap();
    let cores = server.core_utilization().len() as u64;
    let n = 64usize;
    let round = |count: usize| -> Vec<Request> {
        (0..count)
            .map(|i| {
                let data: Vec<f32> = (0..n).map(|j| (i + j) as f32 * 0.5).collect();
                Request::new(KernelSpec::Reduction { n })
                    .load(0, f32_bits(&data))
                    .unload(n, 1)
                    .at(i as u64 * 400)
            })
            .collect()
    };

    let first = server.serve(round(24)).unwrap();
    assert_eq!(first.results.len(), 24);
    let warm = server.reuse_stats();
    // Every served job made exactly one reuse decision...
    assert_eq!(warm.hits + warm.misses, 24);
    // ...and only the first job per core could miss.
    assert!(
        warm.misses <= cores,
        "misses {} exceed the core count {cores}",
        warm.misses
    );
    assert!(warm.hits > warm.misses, "reuse must dominate a one-spec workload");

    // A second identical round: every core already holds the kernel, so
    // steady-state serving adds only hits — zero program reloads per
    // (core, fingerprint).
    server.reset_timeline();
    let second = server.serve(round(24)).unwrap();
    assert_eq!(second.results.len(), 24);
    let steady = server.reuse_stats();
    assert_eq!(
        steady.misses, warm.misses,
        "steady state must not reload programs"
    );
    assert_eq!(steady.hits, warm.hits + 24);
}

#[test]
fn reuse_counters_match_between_sequential_and_parallel() {
    // The reuse decision is made in submission order in both dispatch
    // paths, so the counters — like every other observable — are
    // bit-identical across them.
    let run = |sequential: bool| {
        let mut server = Server::builder().sequential(sequential).build().unwrap();
        let report = server.serve(trace(0xCAFE, 30)).unwrap();
        assert!(report.telemetry.completed > 0);
        server.reuse_stats()
    };
    assert_eq!(run(true), run(false));
}

// ---------------------------------------------------------------
// Serving semantics details.
// ---------------------------------------------------------------

#[test]
fn latency_decomposition_is_consistent() {
    let mut server = Server::builder().build().unwrap();
    let report = server.serve(trace(0x77, 20)).unwrap();
    for r in &report.results {
        assert!(r.start >= r.arrival, "{}: started before arrival", r.id);
        assert!(r.start >= r.dispatched, "{}: started before dispatch", r.id);
        assert!(r.end > r.start, "{}: zero-length service", r.id);
        assert_eq!(r.queue_wait() + r.service(), r.e2e(), "{}", r.id);
    }
    let t = &report.telemetry;
    assert_eq!(t.completed, report.results.len() as u64);
    assert_eq!(t.e2e.count(), t.completed);
    assert!(t.e2e.p50() <= t.e2e.p99());
    assert!(t.jobs_per_s(server.bus_mhz()) > 0.0);
    // Utilization is finite and the idle gaps keep it below 1.
    for u in server.core_utilization() {
        assert!((0.0..=1.0).contains(&u), "{u}");
    }
}

// ---------------------------------------------------------------
// (f) Persistent data plane: pool lifecycle + superplan sharing.
// ---------------------------------------------------------------

#[test]
fn repeated_serve_rounds_spawn_the_worker_pool_exactly_once() {
    let mut server = Server::builder().build().unwrap();
    for round in 0u64..3 {
        server.reset_timeline();
        let report = server.serve(trace(0x9001 + round, 20)).unwrap();
        assert!(report.telemetry.completed > 0);
        assert_eq!(server.pool_spawns(), 1, "round {round} respawned the pool");
    }
    assert_eq!(server.pool_revives(), 0);

    // The sequential reference path never spawns a pool at all.
    let mut seq = Server::builder().sequential(true).build().unwrap();
    seq.serve(trace(0x9001, 20)).unwrap();
    assert_eq!(seq.pool_spawns(), 0);
}

#[test]
fn panicking_job_poisons_its_core_for_the_batch_and_revives_after() {
    use egpu::coordinator::{Coordinator, Job};
    use egpu::kernels::reduction::reduction;
    use egpu::sim::config::MemoryMode;
    use egpu::sim::EgpuConfig;

    let n = 64usize;
    let data = f32_bits(&(0..n).map(|i| i as f32).collect::<Vec<_>>());
    let job = |stream: u64| {
        Job::new(reduction(n))
            .load(0, data.clone())
            .unload(n, 1)
            .on_stream(stream)
    };
    let run = |parallel: bool| {
        let cfg = EgpuConfig::benchmark(MemoryMode::Dp, false);
        let mut c = Coordinator::new(cfg, 2).unwrap();
        c.set_parallel(parallel);
        // Batch 1: the injected panic fails its batch with the
        // contained worker-panic error.
        c.submit(job(0));
        c.submit(job(1).inject_panic());
        c.submit(job(0));
        let err = c.run_all().unwrap_err();
        // Batch 2 on the same coordinator: the poisoned core revives
        // with the next batch window and everything serves.
        c.submit(job(0));
        c.submit(job(1));
        let rs = c.run_all().unwrap();
        assert_eq!(rs.len(), 2);
        (err.message, c.pool_spawns(), c.pool_revives())
    };

    let (par_msg, spawns, revives) = run(true);
    assert!(
        par_msg.contains("panicked in its worker"),
        "unexpected error: {par_msg}"
    );
    // Job panics poison the core for the batch but never kill the
    // thread: one pool for the coordinator's lifetime, zero revives.
    assert_eq!((spawns, revives), (1, 0));

    // Sequential parity: the contained panic surfaces as the same
    // error, with no pool involved.
    let (seq_msg, seq_spawns, _) = run(false);
    assert_eq!(seq_msg, par_msg);
    assert_eq!(seq_spawns, 0);
}

#[test]
fn superplan_counters_match_between_sequential_and_parallel() {
    // Superplan cache lookups happen under the cache lock in dispatch
    // order, so compiles/hits/entries — and the summed per-core
    // rebuild/fast-skip activity — are bit-identical across dispatch
    // modes, like every other serving observable.
    let run = |sequential: bool| {
        let mut server = Server::builder().sequential(sequential).build().unwrap();
        let report = server.serve(trace(0x5EED, 30)).unwrap();
        assert!(report.telemetry.completed > 0);
        (server.superplan_stats(), server.superplan_activity())
    };
    assert_eq!(run(true), run(false));
}

#[test]
fn steady_state_serving_performs_zero_superplan_recompiles() {
    let mut server = Server::builder().build().unwrap();
    let first = server.serve(trace(0x1DEA, 40)).unwrap();
    assert!(first.telemetry.completed > 0);
    let warm = server.superplan_stats();
    assert!(warm.compiles > 0);
    // One fused-trace compile per distinct (kernel, config
    // fingerprint, threads) triple: every compile is a distinct
    // resident entry, and repeat attachments within the round hit.
    assert_eq!(warm.compiles, warm.entries as u64);
    let warm_act = server.superplan_activity();

    // A second identical round on a fresh measurement window is served
    // entirely from resident artifacts: zero new superplan compiles.
    server.reset_timeline();
    let second = server.serve(trace(0x1DEA, 40)).unwrap();
    assert_eq!(second, first, "warm replay must be bit-identical");
    let steady = server.superplan_stats();
    assert_eq!(
        steady.compiles, warm.compiles,
        "steady-state serving must not recompile fused traces"
    );
    assert_eq!(steady.entries, warm.entries);
    let steady_act = server.superplan_activity();
    assert!(
        steady_act.fast_skips > warm_act.fast_skips,
        "warm rounds must reuse resident superplans in place"
    );
}

#[test]
fn serve_results_are_correct_not_just_timed() {
    // Reductions through the serving path produce the same sums a
    // direct launch would: serving reorders and batches, it must not
    // corrupt data.
    let mut server = Server::builder().build().unwrap();
    let n = 64usize;
    let reqs: Vec<Request> = (0..6)
        .map(|i| {
            let data: Vec<f32> = (0..n).map(|j| (i * n + j) as f32 * 0.25).collect();
            Request::new(KernelSpec::Reduction { n })
                .load(0, f32_bits(&data))
                .unload(n, 1)
                .at(i as u64 * 500)
        })
        .collect();
    let report = server.serve(reqs).unwrap();
    assert_eq!(report.results.len(), 6);
    for r in &report.results {
        let i = r.id;
        let want: f32 = (0..n).map(|j| (i * n + j) as f32 * 0.25).sum();
        let got = f32::from_bits(r.outputs[0][0]);
        assert!(
            (got - want).abs() < want.abs() * 1e-3 + 1e-2,
            "request {i}: {got} vs {want}"
        );
    }
}
