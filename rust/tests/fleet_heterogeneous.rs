//! Heterogeneous-fleet integration: per-core static configurations,
//! feature-aware routing, wall-clock-aware placement, and the kernel
//! specialization cache.
//!
//! The load-bearing properties, per ISSUE 4's acceptance criteria:
//!
//! 1. A mixed DP/QP fleet produces **bit-identical** registers, shared
//!    memory, outputs and per-job cycle counts to running each job
//!    alone on a solo `Gpu` of its placed core's configuration.
//! 2. A predicated job is never placed on a `predicate_levels == 0`
//!    core (and a DOT job never on a core without the extension).
//! 3. Homogeneous fleets stay bit-identical to the legacy
//!    single-config coordinator, parallel or sequential.
//! 4. The kernel cache compiles each `(spec, fingerprint)` exactly
//!    once across repeated stream submissions and batches.

use std::collections::HashMap;

use egpu::api::{FleetBuilder, Gpu, KernelCache, KernelSpec};
use egpu::coordinator::{Coordinator, Job};
use egpu::harness::{demo_job_io, demo_specs, Rng};
use egpu::kernels::{f32_bits, fft, reduction};
use egpu::sim::{EgpuConfig, MemoryMode};

/// 771 MHz DP core with every feature the batch needs.
fn dp_full() -> EgpuConfig {
    let mut cfg = EgpuConfig::benchmark(MemoryMode::Dp, true);
    cfg.predicate_levels = 8;
    cfg.name = "DP-full".into();
    cfg
}

/// 600 MHz QP core without predicates or extension cores.
fn qp_plain() -> EgpuConfig {
    let mut cfg = EgpuConfig::benchmark(MemoryMode::Qp, false);
    cfg.sfu = false;
    cfg.name = "QP-plain".into();
    cfg
}

/// The property at the heart of the refactor: every job on a mixed
/// DP/QP fleet is bit-identical — cycles, outputs, and the placed
/// core's final register file and shared memory — to replaying that
/// core's job sequence on a solo `Gpu` of the same configuration.
#[test]
fn mixed_fleet_matches_solo_execution_bit_for_bit() {
    for seed in [0xF1EE7u64, 0x5EED2] {
        let mut rng = Rng::new(seed);
        let mut fleet = FleetBuilder::new().core(dp_full()).core(qp_plain()).build().unwrap();

        let menu = demo_specs(64);
        let mut submitted = Vec::new();
        for j in 0..8 {
            let spec = menu[(j + (rng.next_u32() as usize % menu.len())) % menu.len()];
            let (loads, unloads) = demo_job_io(&spec, &mut rng);
            let mut launch = fleet.launch_spec_any(spec).unwrap();
            for (base, data) in &loads {
                launch = launch.input_words(*base, data.clone());
            }
            for &(base, len) in &unloads {
                launch = launch.output(base, len);
            }
            launch.submit();
            submitted.push((spec, loads, unloads));
        }
        let reports = fleet.sync().unwrap();
        assert_eq!(reports.len(), submitted.len());

        // Replay each core's job sequence on a solo Gpu of that core's
        // configuration, in submission order (= the worker's order).
        let mut solo: HashMap<usize, Gpu> = HashMap::new();
        for (r, (spec, loads, unloads)) in reports.iter().zip(&submitted) {
            let cfg = fleet.core_configs()[r.core].clone();
            assert!(cfg.satisfies(&r.requires), "routed to an incapable core");
            let gpu = solo.entry(r.core).or_insert_with(|| Gpu::new(&cfg).unwrap());
            gpu.clear_shared();
            for (base, data) in loads {
                gpu.write_words(*base, data).unwrap();
            }
            let solo_report = gpu.launch_spec(spec).unwrap().run().unwrap();
            assert_eq!(
                solo_report.compute_cycles, r.compute_cycles,
                "seed {seed:#x}: '{}' cycles differ on core {}",
                r.name, r.core
            );
            assert_eq!(solo_report.stats.hazards, r.stats.hazards);
            for (k, &(base, len)) in unloads.iter().enumerate() {
                let words = gpu.read_words(base, len).unwrap();
                assert_eq!(
                    words,
                    r.outputs[k],
                    "seed {seed:#x}: '{}' output {k} differs",
                    r.name
                );
            }
        }

        // Final architectural state per used core: registers and shared
        // memory bit-identical between the fleet machine and the solo
        // replay.
        for (&core, gpu) in &solo {
            let fleet_m = fleet.coordinator().core_machine(core);
            let solo_m = gpu.machine();
            let shared_len = solo_m.shared().len();
            assert_eq!(
                fleet_m.shared().read_block(0, shared_len),
                solo_m.shared().read_block(0, shared_len),
                "seed {seed:#x}: core {core} shared memory differs"
            );
            let (threads, regs) = (solo_m.regs().threads(), solo_m.regs().regs_per_thread());
            for t in 0..threads {
                for reg in 0..regs {
                    assert_eq!(
                        fleet_m.regs().read_thread(t, reg as u8),
                        solo_m.regs().read_thread(t, reg as u8),
                        "seed {seed:#x}: core {core} r{reg} of thread {t} differs"
                    );
                }
            }
        }
    }
}

#[test]
fn predicated_jobs_never_land_on_predicateless_cores() {
    // Whichever side of the fleet the capable core sits on, the
    // predicated sort routes to it.
    for (cfgs, want_core) in [
        (vec![qp_plain(), dp_full()], 1usize),
        (vec![dp_full(), qp_plain()], 0),
    ] {
        let mut fleet = FleetBuilder::new()
            .core(cfgs[0].clone())
            .core(cfgs[1].clone())
            .build()
            .unwrap();
        let mut rng = Rng::new(7);
        for _ in 0..3 {
            let spec = KernelSpec::Bitonic { n: 64 };
            let (loads, unloads) = demo_job_io(&spec, &mut rng);
            let mut launch = fleet.launch_spec_any(spec).unwrap();
            for (base, data) in loads {
                launch = launch.input_words(base, data);
            }
            for (base, len) in unloads {
                launch = launch.output(base, len);
            }
            launch.submit();
        }
        let reports = fleet.sync().unwrap();
        for r in &reports {
            assert_eq!(r.core, want_core, "predicated job on pred-0 core");
            assert!(fleet.core_configs()[r.core].predicate_levels > 0);
        }
    }

    // A DOT kernel routes the same way...
    let mut fleet = FleetBuilder::new().core(qp_plain()).core(dp_full()).build().unwrap();
    let spec = KernelSpec::ReductionDot { n: 64 };
    let mut rng = Rng::new(9);
    let (loads, unloads) = demo_job_io(&spec, &mut rng);
    let mut launch = fleet.launch_spec_any(spec).unwrap();
    for (base, data) in loads {
        launch = launch.input_words(base, data);
    }
    for (base, len) in unloads {
        launch = launch.output(base, len);
    }
    launch.submit();
    assert_eq!(fleet.sync().unwrap()[0].core, 1);

    // ...and with no capable core anywhere, dispatch errors up front.
    let mut fleet = FleetBuilder::new().cores(qp_plain(), 2).build().unwrap();
    fleet.launch_spec_any(KernelSpec::Bitonic { n: 64 }).unwrap().submit();
    let err = fleet.sync().unwrap_err();
    assert!(err.to_string().contains("predicate"), "{err}");
}

#[test]
fn wall_clock_placement_prefers_the_faster_core() {
    // Both cores idle; the 600 MHz QP core is listed first. The DP
    // core's wall-clock completion estimate is earlier, so it wins
    // despite the first-index tie-break.
    let mut fleet = FleetBuilder::new().core(qp_plain()).core(dp_full()).build().unwrap();
    let spec = KernelSpec::Reduction { n: 64 };
    let mut rng = Rng::new(11);
    let (loads, unloads) = demo_job_io(&spec, &mut rng);
    let mut launch = fleet.launch_spec_any(spec).unwrap();
    for (base, data) in loads {
        launch = launch.input_words(base, data);
    }
    for (base, len) in unloads {
        launch = launch.output(base, len);
    }
    launch.submit();
    assert_eq!(fleet.sync().unwrap()[0].core, 1, "771 MHz must outbid 600 MHz");

    // On a homogeneous pair the tie-break stays first-index — the
    // historical earliest-free behavior.
    let mut fleet = FleetBuilder::new().cores(dp_full(), 2).build().unwrap();
    let (loads, unloads) = demo_job_io(&spec, &mut rng);
    let mut launch = fleet.launch_spec_any(spec).unwrap();
    for (base, data) in loads {
        launch = launch.input_words(base, data);
    }
    for (base, len) in unloads {
        launch = launch.output(base, len);
    }
    launch.submit();
    assert_eq!(fleet.sync().unwrap()[0].core, 0);
}

#[test]
fn homogeneous_fleet_is_bit_identical_to_the_legacy_coordinator() {
    // Same 6-job batch through (a) the legacy homogeneous constructor
    // with parallel dispatch, (b) Coordinator::fleet of identical
    // configs, (c) the sequential reference path: identical placement,
    // timeline and outputs everywhere.
    let cfg = EgpuConfig::benchmark(MemoryMode::Dp, false);
    let run = |mut c: Coordinator| {
        let mut rng = Rng::new(0xBEEF);
        for i in 0..6u64 {
            let n = 64;
            let data: Vec<f32> = (0..n).map(|_| rng.f32_in(-4.0, 4.0)).collect();
            c.submit(
                Job::new(reduction::reduction(n))
                    .load(0, f32_bits(&data))
                    .unload(n, 1)
                    .on_stream(i % 3),
            );
        }
        let rs = c.run_all().unwrap();
        (rs, c.makespan())
    };
    let (legacy, span_a) = run(Coordinator::new(cfg.clone(), 3).unwrap());
    let (fleet, span_b) = run(Coordinator::fleet(vec![cfg.clone(); 3]).unwrap());
    let (seq, span_c) = {
        let mut c = Coordinator::new(cfg, 3).unwrap();
        c.set_parallel(false);
        run(c)
    };
    assert_eq!(span_a, span_b);
    assert_eq!(span_a, span_c);
    for other in [&fleet, &seq] {
        assert_eq!(legacy.len(), other.len());
        for (a, b) in legacy.iter().zip(other.iter()) {
            assert_eq!(a.core, b.core);
            assert_eq!(a.stream, b.stream);
            assert_eq!(a.compute_cycles, b.compute_cycles);
            assert_eq!(a.bus_cycles, b.bus_cycles);
            assert_eq!((a.start, a.end), (b.start, b.end));
            assert_eq!(a.outputs, b.outputs);
            assert_eq!(a.requires, b.requires);
        }
    }
}

#[test]
fn cache_compiles_each_specialization_exactly_once() {
    let cache = KernelCache::shared();
    let mut fleet = FleetBuilder::new()
        .cores(dp_full(), 2)
        .cores(qp_plain(), 2)
        .kernel_cache(cache.clone())
        .build()
        .unwrap();

    let spec = KernelSpec::Reduction { n: 64 };
    let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
    // Streams pinned to one DP and one QP core force both fingerprints
    // into play; two batches of repeated submissions exercise reuse.
    let s_dp = fleet.stream_on_core(0).unwrap();
    let s_qp = fleet.stream_on_core(3).unwrap();
    let mut jobs = 0u64;
    for _batch in 0..2 {
        for s in [s_dp, s_qp] {
            for _ in 0..3 {
                fleet
                    .launch_spec(&s, spec)
                    .unwrap()
                    .input_f32(0, &data)
                    .output(64, 1)
                    .submit();
                jobs += 1;
            }
        }
        let reports = fleet.sync().unwrap();
        for r in &reports {
            match r.stream {
                Some(id) if id == s_dp.id() => assert_eq!(r.core, 0),
                Some(id) if id == s_qp.id() => assert_eq!(r.core, 3),
                other => panic!("unexpected stream {other:?}"),
            }
        }
    }

    let stats = cache.stats();
    // Exactly two specializations exist: (reduction-64, DP/32-reg) —
    // which the reference requirement-extraction build (core 0's
    // fingerprint) shares — and (reduction-64, QP/32-reg). Every
    // further lookup hit.
    assert_eq!(stats.compiles, 2, "{stats:?}");
    assert_eq!(stats.entries, 2, "{stats:?}");
    // Each job looks up twice (canonical + placed-core specialization).
    assert_eq!(stats.hits, 2 * jobs - stats.compiles, "{stats:?}");

    // The QP specialization is genuinely different object identity-wise
    // from the DP one, and both run to the same numeric result.
    let dp_k = cache.get(&spec, &dp_full()).unwrap();
    let qp_k = cache.get(&spec, &qp_plain()).unwrap();
    assert_eq!(cache.stats().compiles, 2, "post-hoc lookups must hit");
    assert_eq!(dp_k.name, qp_k.name);
}

#[test]
fn solo_gpu_launch_spec_reuses_its_cache() {
    let mut gpu = Gpu::new(&dp_full()).unwrap();
    let spec = KernelSpec::Fft { n: 64 };
    let re = vec![0.5f32; 64];
    let im = vec![0f32; 64];
    for _ in 0..3 {
        for (base, words) in fft::shared_init(&re, &im) {
            gpu.write_words(base, &words).unwrap();
        }
        gpu.launch_spec(&spec).unwrap().run().unwrap();
    }
    let stats = gpu.kernel_cache().stats();
    assert_eq!(stats.compiles, 1, "{stats:?}");
    assert_eq!(stats.hits, 2, "{stats:?}");
}

#[test]
fn pinned_stream_rejects_jobs_its_core_cannot_run() {
    let mut fleet = FleetBuilder::new().core(dp_full()).core(qp_plain()).build().unwrap();
    let s = fleet.stream_on_core(1).unwrap(); // QP: no predicates
    fleet
        .launch_spec(&s, KernelSpec::Bitonic { n: 64 })
        .unwrap()
        .input_words(0, vec![3, 1, 2, 0])
        .output(0, 4)
        .submit();
    let err = fleet.sync().unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("pinned") && msg.contains("predicate"), "{msg}");

    // Pinning out of range is refused up front.
    assert!(fleet.stream_on_core(9).is_err());
}

#[test]
fn fleet_configs_round_trip_through_json() {
    use egpu::sim::config_json::{configs_from_json, fleet_to_json};
    let fleet = vec![dp_full(), dp_full(), qp_plain()];
    let parsed = configs_from_json(&fleet_to_json(&fleet)).unwrap();
    assert_eq!(parsed, fleet);
    let mut builder = FleetBuilder::new();
    for cfg in parsed {
        builder = builder.core(cfg);
    }
    let array = builder.build().unwrap();
    assert_eq!(array.num_cores(), 3);
    assert_eq!(array.core_configs()[2].name, "QP-plain");
    assert_eq!(array.coordinator().core_mhz(0), 771.0);
    assert_eq!(array.coordinator().core_mhz(2), 600.0);
    assert_eq!(array.coordinator().bus_mhz(), 771.0);

    // An empty fleet is an error, not a panic.
    assert!(FleetBuilder::new().build().is_err());
}

#[test]
fn heterogeneous_timeline_is_wall_clock_consistent() {
    // A QP job's bus-timeline occupancy must be >= its core cycles
    // (600 MHz work takes longer on the 771 MHz bus timeline), while a
    // DP job occupies exactly its cycles plus DMA.
    let mut fleet = FleetBuilder::new().core(dp_full()).core(qp_plain()).build().unwrap();
    let s_dp = fleet.stream_on_core(0).unwrap();
    let s_qp = fleet.stream_on_core(1).unwrap();
    let data: Vec<f32> = (0..64).map(|i| i as f32 * 0.5).collect();
    for s in [s_dp, s_qp] {
        fleet
            .launch_spec(&s, KernelSpec::Reduction { n: 64 })
            .unwrap()
            .input_f32(0, &data)
            .output(64, 1)
            .submit();
    }
    let reports = fleet.sync().unwrap();
    let dp = reports.iter().find(|r| r.core == 0).unwrap();
    let qp = reports.iter().find(|r| r.core == 1).unwrap();
    assert_eq!(dp.end - dp.start, dp.compute_cycles + dp.bus_cycles);
    let qp_span = qp.end - qp.start;
    assert!(
        qp_span > qp.compute_cycles + qp.bus_cycles,
        "QP compute must stretch on the 771 MHz bus timeline: span \
         {qp_span}, cycles {} + dma {}",
        qp.compute_cycles,
        qp.bus_cycles
    );
    // Exact conversion: ceil(cycles * 771 / 600) + DMA.
    let want = (qp.compute_cycles as u128 * 771_000).div_ceil(600_000) as u64 + qp.bus_cycles;
    assert_eq!(qp_span, want);
    // Utilization covers both cores and sums sensibly.
    let util = fleet.core_utilization();
    assert_eq!(util.len(), 2);
    assert!(util.iter().all(|&u| u > 0.0 && u <= 1.0), "{util:?}");
}
